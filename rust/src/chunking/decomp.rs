//! The decomposition and raw span algebra: the 1-D row-band
//! [`Decomposition`] and its 2-D row x column generalization
//! [`Decomposition2d`], built from the same per-axis split/validation.

use crate::core::geom::{Rect, RowSpan};
use crate::stencil::StencilKind;
use crate::util::threads::split_range;
use anyhow::{bail, Result};

/// Validate and build one axis of a decomposition: `parts` near-equal
/// pieces of an `extent`-cell axis for a stencil of `radius`. Returns the
/// `parts + 1` bounds. This is the single constructor error path shared
/// by the 1-D and 2-D variants, so both reject malformed shapes with the
/// same messages (naming the violated `radius`/extent constraint instead
/// of a bare assert).
fn split_axis(extent: usize, parts: usize, radius: usize, axis: &str) -> Result<Vec<usize>> {
    if radius == 0 {
        bail!("radius must be positive (got 0)");
    }
    if parts == 0 {
        bail!("chunk count along {axis} must be positive (got 0)");
    }
    if parts > extent {
        bail!(
            "chunk count {parts} along {axis} exceeds the {extent}-cell extent: \
             every chunk needs at least one owned cell"
        );
    }
    if extent <= 2 * radius {
        bail!(
            "{axis} extent {extent} must exceed the 2*radius = {} Dirichlet boundary ring \
             (no interior cell would remain)",
            2 * radius
        );
    }
    let pieces = split_range(0, extent, parts);
    debug_assert_eq!(pieces.len(), parts);
    let mut bounds: Vec<usize> = pieces.iter().map(|&(a, _)| a).collect();
    bounds.push(extent);
    Ok(bounds)
}

/// A 1-D (row-band) decomposition of a `rows x cols` grid into `d` chunks
/// for a stencil of radius `radius`.
#[derive(Debug, Clone)]
pub struct Decomposition {
    rows: usize,
    cols: usize,
    d: usize,
    radius: usize,
    /// `d + 1` chunk bounds: chunk `i` owns rows `[bounds[i], bounds[i+1])`.
    bounds: Vec<usize>,
}

impl Decomposition {
    /// Near-equal split with a validated error path: rejects `d == 0`,
    /// `d > rows`, `radius == 0`, and grids whose rows or cols do not
    /// exceed the `2*radius` Dirichlet ring.
    pub fn try_new(rows: usize, cols: usize, d: usize, radius: usize) -> Result<Self> {
        let bounds = split_axis(rows, d, radius, "rows")?;
        // The column axis is not split, but the kernel interior still
        // needs at least one column between the Dirichlet rings.
        split_axis(cols, 1, radius, "cols")?;
        Ok(Self { rows, cols, d, radius, bounds })
    }

    /// Panicking [`Self::try_new`] (the original constructor contract,
    /// kept for infallible call sites — planners and tests).
    pub fn new(rows: usize, cols: usize, d: usize, radius: usize) -> Self {
        Self::try_new(rows, cols, d, radius)
            .unwrap_or_else(|e| panic!("invalid decomposition: {e}"))
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn n_chunks(&self) -> usize {
        self.d
    }

    pub fn radius(&self) -> usize {
        self.radius
    }

    /// Rows owned by chunk `i`.
    pub fn owned(&self, i: usize) -> RowSpan {
        RowSpan::new(self.bounds[i], self.bounds[i + 1])
    }

    /// Smallest chunk height.
    pub fn min_chunk_rows(&self) -> usize {
        (0..self.d).map(|i| self.owned(i).len()).min().unwrap()
    }

    /// Skirt height `h = steps * radius` for an epoch of `steps`.
    pub fn skirt(&self, steps: usize) -> usize {
        steps * self.radius
    }

    /// Check the feasibility precondition for an epoch of `steps` TB steps:
    /// the skirt plus one radius must fit inside every chunk, so compute
    /// windows stay affine in the step index (paper constraint
    /// `W_halo * S_TB <= D_chk`, tightened by `r` for the Dirichlet ring).
    pub fn feasible(&self, steps: usize) -> bool {
        self.skirt(steps) + self.radius <= self.min_chunk_rows()
    }

    /// Assert feasibility with a readable message.
    pub fn check(&self, steps: usize) {
        assert!(
            self.feasible(steps),
            "infeasible: skirt {} + r {} > min chunk {} (d={}, steps={})",
            self.skirt(steps),
            self.radius,
            self.min_chunk_rows(),
            self.d,
            steps
        );
    }

    // ---------------------------------------------------------------
    // SO2DR (trapezoid) spans, parameterized by the epoch's step count.
    // ---------------------------------------------------------------

    /// Rows resident on the device for chunk `i` during an epoch of
    /// `steps`: owned rows plus the `h`-row skirt on each side (clamped).
    pub fn so2dr_resident(&self, i: usize, steps: usize) -> RowSpan {
        let h = self.skirt(steps) as i64;
        let o = self.owned(i);
        RowSpan::clamped(o.lo as i64 - h, o.hi as i64 + h, self.rows)
    }

    /// Rows transferred host→device for chunk `i`: the resident span minus
    /// what the region-sharing buffer provides (raw rows saved by chunk
    /// `i-1`). Chunk 0 transfers its whole resident span. Per epoch the
    /// HtoD spans partition `[0, rows)` — zero redundant transfer.
    pub fn so2dr_htod(&self, i: usize, steps: usize) -> RowSpan {
        let h = self.skirt(steps) as i64;
        let o = self.owned(i);
        if i == 0 {
            RowSpan::clamped(0, o.hi as i64 + h, self.rows)
        } else {
            RowSpan::clamped(o.lo as i64 + h, o.hi as i64 + h, self.rows)
        }
    }

    /// Raw (epoch-start) rows chunk `i` reads from the region-sharing
    /// buffer: its lower skirt plus its own first `h` rows, all saved by
    /// chunk `i-1`. Empty for chunk 0.
    pub fn so2dr_rs_read(&self, i: usize, steps: usize) -> RowSpan {
        if i == 0 {
            return RowSpan::empty();
        }
        let h = self.skirt(steps) as i64;
        let o = self.owned(i);
        RowSpan::clamped(o.lo as i64 - h, o.lo as i64 + h, self.rows)
    }

    /// Raw rows chunk `i` writes to the region-sharing buffer for chunk
    /// `i+1` (must happen before its kernels overwrite them). Empty for the
    /// last chunk.
    pub fn so2dr_rs_write(&self, i: usize, steps: usize) -> RowSpan {
        if i + 1 == self.d {
            return RowSpan::empty();
        }
        let h = self.skirt(steps) as i64;
        let b = self.bounds[i + 1] as i64;
        RowSpan::clamped(b - h, b + h, self.rows)
    }

    /// Rows transferred device→host after the epoch: exactly the owned rows.
    pub fn so2dr_dtoh(&self, i: usize) -> RowSpan {
        self.owned(i)
    }

    /// Compute window (rows) for chunk `i` at TB step `s` (1-based,
    /// `1 <= s <= steps`): the trapezoid `[a_i - (steps-s)*r,
    /// a_{i+1} + (steps-s)*r)`, clamped to the Dirichlet interior
    /// `[r, rows-r)`.
    pub fn so2dr_window(&self, i: usize, steps: usize, s: usize) -> RowSpan {
        assert!((1..=steps).contains(&s));
        let grow = ((steps - s) * self.radius) as i64;
        let o = self.owned(i);
        let lo = o.lo as i64 - grow;
        let hi = o.hi as i64 + grow;
        let r = self.radius as i64;
        RowSpan::clamped(lo.max(r), hi.min(self.rows as i64 - r), self.rows)
    }

    /// Redundant rows computed at step `s` across all chunk boundaries
    /// (each boundary overlap is `2*(steps-s)*r` rows, clamped by the
    /// interior). Used to cross-check the closed-form redundancy model.
    pub fn so2dr_redundant_rows(&self, steps: usize, s: usize) -> usize {
        let mut total = 0usize;
        for i in 0..self.d.saturating_sub(1) {
            let a = self.so2dr_window(i, steps, s);
            let b = self.so2dr_window(i + 1, steps, s);
            total += a.intersect(&b).len();
        }
        total
    }

    // ---------------------------------------------------------------
    // ResReu (skewed parallelogram) spans.
    // ---------------------------------------------------------------

    /// Rows resident for chunk `i` under ResReu: owned rows plus the lower
    /// working space of `h + r` rows (windows shift downward by `h` over
    /// the epoch and the final window still reads `r` rows below itself).
    /// The last chunk additionally keeps its bottom rows (its window's
    /// upper edge does not shift).
    pub fn resreu_resident(&self, i: usize, steps: usize) -> RowSpan {
        let h = (self.skirt(steps) + self.radius) as i64;
        let o = self.owned(i);
        RowSpan::clamped(o.lo as i64 - h, o.hi as i64, self.rows)
    }

    /// HtoD span under ResReu: exactly the owned rows (intermediate halo
    /// data arrives through the region-sharing buffer).
    pub fn resreu_htod(&self, i: usize) -> RowSpan {
        self.owned(i)
    }

    /// Compute window at step `s` (1-based): `[a_i - s*r, a_{i+1} - s*r)`
    /// shifted by the skew; chunk 0's lower edge clamps at the interior
    /// boundary and the last chunk's upper edge stays at `rows - r`.
    pub fn resreu_window(&self, i: usize, steps: usize, s: usize) -> RowSpan {
        assert!((1..=steps).contains(&s));
        let shift = (s * self.radius) as i64;
        let o = self.owned(i);
        let r = self.radius as i64;
        let lo = if i == 0 { r } else { o.lo as i64 - shift };
        let hi = if i + 1 == self.d { self.rows as i64 - r } else { o.hi as i64 - shift };
        RowSpan::clamped(lo.max(r), hi.min(self.rows as i64 - r), self.rows)
    }

    /// Rows (time `s-1` data) chunk `i` reads from the RS buffer before
    /// step `s`: `2r` rows below its shifted window, produced by chunk
    /// `i-1`. Empty for chunk 0.
    pub fn resreu_rs_read(&self, i: usize, s: usize) -> RowSpan {
        if i == 0 {
            return RowSpan::empty();
        }
        let a = self.bounds[i] as i64;
        let r = self.radius as i64;
        let s = s as i64;
        RowSpan::clamped(a - s * r - r, a - (s - 1) * r, self.rows)
    }

    /// Rows (time `s-1` data) chunk `i` writes to the RS buffer before
    /// step `s` for chunk `i+1`; by construction
    /// `resreu_rs_write(i, s) == resreu_rs_read(i+1, s)`. Empty for the
    /// last chunk.
    pub fn resreu_rs_write(&self, i: usize, s: usize) -> RowSpan {
        if i + 1 == self.d {
            return RowSpan::empty();
        }
        let b = self.bounds[i + 1] as i64;
        let r = self.radius as i64;
        let s = s as i64;
        RowSpan::clamped(b - s * r - r, b - (s - 1) * r, self.rows)
    }

    /// DtoH span after an epoch of `steps`: the skew-shifted owned rows
    /// (chunk 0 keeps its top, the last chunk keeps its bottom); the spans
    /// partition `[0, rows)`.
    pub fn resreu_dtoh(&self, i: usize, steps: usize) -> RowSpan {
        let h = self.skirt(steps) as i64;
        let o = self.owned(i);
        let lo = if i == 0 { 0 } else { o.lo as i64 - h };
        let hi = if i + 1 == self.d { self.rows as i64 } else { o.hi as i64 - h };
        RowSpan::clamped(lo, hi, self.rows)
    }

    // ---------------------------------------------------------------
    // Resident-model spans (cross-epoch residency; see chunking::plan).
    //
    // After an epoch, each chunk's arena holds a *settled* span: rows
    // valid at the epoch-end time step. The settled spans partition
    // `[0, rows)`, so an evicted chunk can spill exactly its settled
    // span and re-fetch it from the host later, while the epoch-start
    // skirt/halo of the next epoch is refreshed from the neighbors'
    // settled spans (fetch spans below) instead of a host round trip.
    // ---------------------------------------------------------------

    /// Rows of chunk `i` that are valid at the current time step in its
    /// arena after an epoch of `steps`: the chunk's writeback span. For
    /// SO2DR this is the owned span (the last trapezoid step computes
    /// exactly the owned rows); for ResReu it is the skew-shifted
    /// [`Self::resreu_dtoh`] span. Settled spans partition `[0, rows)`.
    pub fn settled(&self, scheme: crate::chunking::Scheme, i: usize, steps: usize) -> RowSpan {
        match scheme {
            crate::chunking::Scheme::So2dr => self.owned(i),
            crate::chunking::Scheme::ResReu => self.resreu_dtoh(i, steps),
            crate::chunking::Scheme::InCore => RowSpan::new(0, self.rows),
        }
    }

    /// Lower skirt chunk `i` must fetch at the start of a resident SO2DR
    /// epoch of `steps`: `[lo - h', lo)`, produced (settled) by chunk
    /// `i-1`. Empty for chunk 0 (clamped at the grid edge).
    pub fn so2dr_fetch_low(&self, i: usize, steps: usize) -> RowSpan {
        let h = self.skirt(steps) as i64;
        let o = self.owned(i);
        RowSpan::clamped(o.lo as i64 - h, o.lo as i64, self.rows)
    }

    /// Upper skirt chunk `i` must fetch at the start of a resident SO2DR
    /// epoch of `steps`: `[hi, hi + h')`, settled by chunk `i+1`. Empty
    /// for the last chunk.
    pub fn so2dr_fetch_high(&self, i: usize, steps: usize) -> RowSpan {
        let h = self.skirt(steps) as i64;
        let o = self.owned(i);
        RowSpan::clamped(o.hi as i64, o.hi as i64 + h, self.rows)
    }

    /// Rows chunk `i` must fetch at the start of a resident ResReu epoch:
    /// the previous epoch's windows shifted down by `h_prev`, so the top
    /// `[hi - h_prev, hi)` of the owned span is settled in chunk `i+1`'s
    /// arena. Empty for the last chunk (its window's upper edge does not
    /// shift, so it settles its whole tail itself).
    pub fn resreu_fetch(&self, i: usize, prev_steps: usize) -> RowSpan {
        if i + 1 == self.d {
            return RowSpan::empty();
        }
        let h = self.skirt(prev_steps) as i64;
        let o = self.owned(i);
        RowSpan::clamped(o.hi as i64 - h, o.hi as i64, self.rows)
    }

    /// Uniform chunk-arena height for a whole run with at most `s_max` TB
    /// steps per epoch: tall enough for the largest epoch of any chunk, so
    /// fixed-shape (AOT-compiled) kernels serve every chunk and epoch and
    /// resident arenas keep a stable base across epochs.
    pub fn uniform_buffer_rows(&self, scheme: crate::chunking::Scheme, s_max: usize) -> usize {
        let max_own = (0..self.d).map(|i| self.owned(i).len()).max().unwrap();
        match scheme {
            crate::chunking::Scheme::So2dr => max_own + 2 * s_max * self.radius,
            crate::chunking::Scheme::ResReu => max_own + s_max * self.radius + self.radius,
            crate::chunking::Scheme::InCore => self.rows,
        }
    }

    /// Signed global row of chunk `i`'s arena base under the resident
    /// execution model: fixed across epochs (sized for `s_max`), so data
    /// keeps its arena offset from one epoch to the next.
    pub fn resident_base(
        &self,
        scheme: crate::chunking::Scheme,
        s_max: usize,
        i: usize,
    ) -> i64 {
        let r = self.radius as i64;
        let h = (s_max * self.radius) as i64;
        match scheme {
            crate::chunking::Scheme::So2dr => self.owned(i).lo as i64 - h,
            crate::chunking::Scheme::ResReu => self.owned(i).lo as i64 - h - r,
            crate::chunking::Scheme::InCore => 0,
        }
    }

    /// Bytes of one chunk arena (input + output double buffer) at the
    /// uniform height `buf_rows`.
    pub fn arena_bytes(&self, buf_rows: usize) -> u64 {
        2 * (buf_rows * self.cols * 4) as u64
    }

    /// Uncompressed payload bytes of a transfer covering `span` rows.
    /// The codec policy's size thresholds and the planner's byte
    /// accounting go through here so they cannot drift; the executor's
    /// counters and the flattener keep a hoisted `cols * 4` of the same
    /// formula on their hot paths.
    pub fn span_bytes(&self, span: RowSpan) -> u64 {
        (span.len() * self.cols * 4) as u64
    }

    // ---------------------------------------------------------------
    // Paper model quantities (Section III / IV-C).
    // ---------------------------------------------------------------

    /// `D_chk` in bytes for one chunk (f32 elements).
    pub fn chunk_bytes(&self, i: usize) -> u64 {
        (self.owned(i).len() * self.cols * 4) as u64
    }

    /// `W_halo` in bytes: one radius-deep halo region pair
    /// (`2r * cols` elements), the paper's per-TB-step working space.
    pub fn halo_bytes_per_step(&self) -> u64 {
        (2 * self.radius * self.cols * 4) as u64
    }

    /// Device-resident bytes for chunk `i` during an epoch of `steps`
    /// (`D_chk + W_halo*S_TB`), for the memory-capacity constraint.
    pub fn resident_bytes(&self, i: usize, steps: usize, kind: StencilKind) -> u64 {
        let _ = kind; // radius already captured in self.radius
        self.chunk_bytes(i) + self.halo_bytes_per_step() * steps as u64
    }
}

// -------------------------------------------------------------------
// 2-D tile decomposition.
// -------------------------------------------------------------------

/// A 2-D (row x column tile) decomposition of a `rows x cols` grid into
/// `tiles_y x tiles_x` tiles for a stencil of radius `radius` — the
/// product of two per-axis near-equal splits, sharing the 1-D
/// decomposition's span algebra along each axis.
///
/// The SO2DR sharing scheme generalizes as a product of the 1-D scheme:
/// data flows toward higher tile indices along *each* axis, exactly as
/// the row-band scheme flows downward. Per epoch of `S` steps (skirt
/// `h = S*r`), tile `(i, j)`:
///
/// * transfers host-to-device the product of the per-axis *shifted*
///   spans (`[lo+h, hi+h)` per axis, edge tiles clamped) — the HtoD
///   rects partition the grid, zero redundant host transfer;
/// * reads its **north band** `[rlo-h, rlo+h) x [clo-h, chi+h)` from
///   tile `(i-1, j)` and its **west band** `[rlo+h, rhi+h) x
///   [clo-h, clo+h)` from tile `(i, j-1)` through the region-sharing
///   buffer (the west band is a strided column slice of the producer's
///   arena);
/// * publishes the matching south/east bands for `(i+1, j)` and
///   `(i, j+1)` *after* its reads and *before* its kernels — the bands
///   are epoch-start data, extracted before any kernel overwrites them.
///
/// **Corner ownership**: corner blocks are owned by the row bands — the
/// north band spans the tile's full skirted width `[clo-h, chi+h)`, so a
/// diagonal neighbor's `h x h` corner cascades through two band hops
/// (`(i-1,j-1) -> (i-1,j) -> (i,j)`) instead of eight dedicated corner
/// ops. Every tile therefore possesses its full resident rect
/// (`owned` grown by `h`, clamped) after exactly two reads, by induction
/// over the row-major tile order: `HtoD ∪ north ∪ west = resident`,
/// disjointly, and each band lies inside its producer's resident rect.
///
/// Degenerate tilings reproduce the 1-D plans op-for-op: `tiles_x = 1`
/// makes every column span full-width and the west/east bands empty,
/// which is literally the row-band scheme; `tiles_y = 1` is its
/// transpose.
#[derive(Debug, Clone)]
pub struct Decomposition2d {
    rows: usize,
    cols: usize,
    tiles_y: usize,
    tiles_x: usize,
    radius: usize,
    /// `tiles_y + 1` bounds: tile row `i` owns rows `[rb[i], rb[i+1])`.
    row_bounds: Vec<usize>,
    /// `tiles_x + 1` bounds: tile col `j` owns cols `[cb[j], cb[j+1])`.
    col_bounds: Vec<usize>,
}

/// Per-axis span algebra shared by both axes (private: the public
/// surface speaks rects). `h` is the epoch skirt in cells.
fn axis_owned(bounds: &[usize], i: usize) -> RowSpan {
    RowSpan::new(bounds[i], bounds[i + 1])
}

/// Shifted HtoD span: `[lo+h, hi+h)`, the first chunk extended to the
/// axis origin and the last clamped at the extent — identical to the 1-D
/// [`Decomposition::so2dr_htod`] formula.
fn axis_htod(bounds: &[usize], extent: usize, i: usize, h: i64) -> RowSpan {
    let o = axis_owned(bounds, i);
    if i == 0 {
        RowSpan::clamped(0, o.hi as i64 + h, extent)
    } else {
        RowSpan::clamped(o.lo as i64 + h, o.hi as i64 + h, extent)
    }
}

/// Shared band below a chunk's lower bound: `[lo-h, lo+h)`, empty for the
/// first chunk — identical to the 1-D [`Decomposition::so2dr_rs_read`].
fn axis_band(bounds: &[usize], extent: usize, i: usize, h: i64) -> RowSpan {
    if i == 0 {
        return RowSpan::empty();
    }
    let lo = bounds[i] as i64;
    RowSpan::clamped(lo - h, lo + h, extent)
}

/// Resident span: owned grown by `h` on both sides, clamped.
fn axis_resident(bounds: &[usize], extent: usize, i: usize, h: i64) -> RowSpan {
    let o = axis_owned(bounds, i);
    RowSpan::clamped(o.lo as i64 - h, o.hi as i64 + h, extent)
}

impl Decomposition2d {
    /// Validated constructor — the same shared per-axis error path as
    /// [`Decomposition::try_new`], applied to both axes.
    pub fn try_new(
        rows: usize,
        cols: usize,
        tiles_y: usize,
        tiles_x: usize,
        radius: usize,
    ) -> Result<Self> {
        let row_bounds = split_axis(rows, tiles_y, radius, "rows")?;
        let col_bounds = split_axis(cols, tiles_x, radius, "cols")?;
        Ok(Self { rows, cols, tiles_y, tiles_x, radius, row_bounds, col_bounds })
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn radius(&self) -> usize {
        self.radius
    }

    pub fn tiles_y(&self) -> usize {
        self.tiles_y
    }

    pub fn tiles_x(&self) -> usize {
        self.tiles_x
    }

    pub fn n_tiles(&self) -> usize {
        self.tiles_y * self.tiles_x
    }

    /// Row-major flattened tile index.
    pub fn index(&self, i: usize, j: usize) -> usize {
        debug_assert!(i < self.tiles_y && j < self.tiles_x);
        i * self.tiles_x + j
    }

    /// Inverse of [`Self::index`].
    pub fn tile_rc(&self, t: usize) -> (usize, usize) {
        debug_assert!(t < self.n_tiles());
        (t / self.tiles_x, t % self.tiles_x)
    }

    /// Rect owned by tile `t`.
    pub fn owned(&self, t: usize) -> Rect {
        let (i, j) = self.tile_rc(t);
        Rect::of_spans(
            axis_owned(&self.row_bounds, i),
            axis_owned(&self.col_bounds, j),
        )
    }

    /// Skirt depth `h = steps * radius` for an epoch of `steps`.
    pub fn skirt(&self, steps: usize) -> usize {
        steps * self.radius
    }

    pub fn min_tile_rows(&self) -> usize {
        (0..self.tiles_y).map(|i| axis_owned(&self.row_bounds, i).len()).min().unwrap()
    }

    pub fn min_tile_cols(&self) -> usize {
        (0..self.tiles_x).map(|j| axis_owned(&self.col_bounds, j).len()).min().unwrap()
    }

    /// Per-axis feasibility: the skirt plus one radius must fit inside
    /// every tile along *both* axes (the 1-D constraint, per axis).
    pub fn feasible(&self, steps: usize) -> bool {
        let need = self.skirt(steps) + self.radius;
        need <= self.min_tile_rows() && need <= self.min_tile_cols()
    }

    /// Assert feasibility with a readable message.
    pub fn check(&self, steps: usize) {
        assert!(
            self.feasible(steps),
            "infeasible tiling: skirt {} + r {} > min tile {}x{} \
             ({}x{} tiles, steps={})",
            self.skirt(steps),
            self.radius,
            self.min_tile_rows(),
            self.min_tile_cols(),
            self.tiles_y,
            self.tiles_x,
            steps
        );
    }

    /// Rect resident on the device for tile `t` during an epoch of
    /// `steps`: owned grown by the skirt on all four sides (clamped).
    pub fn so2dr_resident(&self, t: usize, steps: usize) -> Rect {
        let h = self.skirt(steps) as i64;
        let (i, j) = self.tile_rc(t);
        Rect::of_spans(
            axis_resident(&self.row_bounds, self.rows, i, h),
            axis_resident(&self.col_bounds, self.cols, j, h),
        )
    }

    /// HtoD rect: the product of the per-axis shifted spans. Per epoch
    /// these rects partition the grid — zero redundant host transfer,
    /// exactly as in 1-D.
    pub fn so2dr_htod(&self, t: usize, steps: usize) -> Rect {
        let h = self.skirt(steps) as i64;
        let (i, j) = self.tile_rc(t);
        Rect::of_spans(
            axis_htod(&self.row_bounds, self.rows, i, h),
            axis_htod(&self.col_bounds, self.cols, j, h),
        )
    }

    /// North band tile `t` reads from tile `(i-1, j)`: its upper `2h`
    /// row band across the full skirted width (corner blocks included —
    /// see the corner-ownership rule in the type docs). Empty for the
    /// first tile row.
    pub fn so2dr_read_north(&self, t: usize, steps: usize) -> Rect {
        let h = self.skirt(steps) as i64;
        let (i, j) = self.tile_rc(t);
        Rect::of_spans(
            axis_band(&self.row_bounds, self.rows, i, h),
            axis_resident(&self.col_bounds, self.cols, j, h),
        )
    }

    /// West band tile `t` reads from tile `(i, j-1)`: the `2h` column
    /// band beside its shifted row span — a strided column slice of the
    /// producer's arena. Empty for the first tile column.
    pub fn so2dr_read_west(&self, t: usize, steps: usize) -> Rect {
        let h = self.skirt(steps) as i64;
        let (i, j) = self.tile_rc(t);
        Rect::of_spans(
            axis_htod(&self.row_bounds, self.rows, i, h),
            axis_band(&self.col_bounds, self.cols, j, h),
        )
    }

    /// South band tile `t` publishes for tile `(i+1, j)` — by
    /// construction `write_south(i, j) == read_north(i+1, j)`. Empty for
    /// the last tile row.
    pub fn so2dr_write_south(&self, t: usize, steps: usize) -> Rect {
        let (i, j) = self.tile_rc(t);
        if i + 1 == self.tiles_y {
            return Rect::new(0, 0, 0, 0);
        }
        self.so2dr_read_north(self.index(i + 1, j), steps)
    }

    /// East band tile `t` publishes for tile `(i, j+1)` — by
    /// construction `write_east(i, j) == read_west(i, j+1)`. Empty for
    /// the last tile column.
    pub fn so2dr_write_east(&self, t: usize, steps: usize) -> Rect {
        let (i, j) = self.tile_rc(t);
        if j + 1 == self.tiles_x {
            return Rect::new(0, 0, 0, 0);
        }
        self.so2dr_read_west(self.index(i, j + 1), steps)
    }

    /// Compute window for tile `t` at TB step `s` (1-based): the 2-D
    /// trapezoid — owned grown by `(steps-s)*r` on all sides, clamped to
    /// the Dirichlet interior `[r, rows-r) x [r, cols-r)`.
    pub fn so2dr_window(&self, t: usize, steps: usize, s: usize) -> Rect {
        assert!((1..=steps).contains(&s));
        let g = ((steps - s) * self.radius) as i64;
        let o = self.owned(t);
        let r = self.radius as i64;
        Rect::clamped(
            (o.r0 as i64 - g).max(r),
            (o.r1 as i64 + g).min(self.rows as i64 - r),
            (o.c0 as i64 - g).max(r),
            (o.c1 as i64 + g).min(self.cols as i64 - r),
            self.rows,
            self.cols,
        )
    }

    /// DtoH rect after the epoch: exactly the owned rect (the final
    /// trapezoid step computes exactly the owned cells) — per epoch the
    /// DtoH rects partition the grid.
    pub fn so2dr_dtoh(&self, t: usize) -> Rect {
        self.owned(t)
    }

    // ---------------------------------------------------------------
    // Resident-model rects (cross-epoch tile residency; see
    // chunking::plan::plan_run_resident_tiles).
    //
    // During an epoch a tile's *settled* region — the cells already at
    // the current time step — shrinks by `radius` per step from all
    // four sides (the 2-D trapezoid), and the final step computes
    // exactly the owned rect. After an epoch each arena therefore
    // holds its owned rect at the epoch-end time, and the settled
    // rects partition the grid — which is what makes spill/re-fetch
    // round trips and the final writeback exact. The next epoch
    // refreshes the `h`-deep ring around the settled rect from the
    // neighbors' arenas in two rounds: west/east *column bands* first
    // (settled data of the row neighbors), then north/south *row
    // bands* at full skirted width — the `h x h` corner blocks ride
    // the row bands, cascading through the column refresh exactly as
    // the staged scheme's corners cascade through its row bands.
    // ---------------------------------------------------------------

    /// Rect of tile `t` that is valid at the current time step in its
    /// arena after an SO2DR epoch: the owned rect (the last trapezoid
    /// step computes exactly the owned cells). Settled rects partition
    /// the grid.
    pub fn settled(&self, t: usize) -> Rect {
        self.owned(t)
    }

    /// West column band tile `t` fetches at the start of a resident
    /// epoch of `steps`: `[r0, r1) x [c0-h, c0)`, settled by tile
    /// `(i, j-1)`. Empty for the first tile column (clamped at the
    /// grid edge).
    pub fn resident_fetch_west(&self, t: usize, steps: usize) -> Rect {
        let h = self.skirt(steps) as i64;
        let o = self.owned(t);
        Rect::clamped(
            o.r0 as i64,
            o.r1 as i64,
            o.c0 as i64 - h,
            o.c0 as i64,
            self.rows,
            self.cols,
        )
    }

    /// East column band tile `t` fetches: `[r0, r1) x [c1, c1+h)`,
    /// settled by tile `(i, j+1)`. Empty for the last tile column.
    pub fn resident_fetch_east(&self, t: usize, steps: usize) -> Rect {
        let h = self.skirt(steps) as i64;
        let o = self.owned(t);
        Rect::clamped(
            o.r0 as i64,
            o.r1 as i64,
            o.c1 as i64,
            o.c1 as i64 + h,
            self.rows,
            self.cols,
        )
    }

    /// North row band tile `t` fetches: `[r0-h, r0) x [c0-h, c1+h)` —
    /// the full skirted width, corners included. Published by tile
    /// `(i-1, j)` *after* its own column fetches (the corner blocks
    /// arrive there through the column refresh). Empty for the first
    /// tile row.
    pub fn resident_fetch_north(&self, t: usize, steps: usize) -> Rect {
        let h = self.skirt(steps) as i64;
        let o = self.owned(t);
        Rect::clamped(
            o.r0 as i64 - h,
            o.r0 as i64,
            o.c0 as i64 - h,
            o.c1 as i64 + h,
            self.rows,
            self.cols,
        )
    }

    /// South row band tile `t` fetches: `[r1, r1+h) x [c0-h, c1+h)`,
    /// published by tile `(i+1, j)`. Empty for the last tile row.
    pub fn resident_fetch_south(&self, t: usize, steps: usize) -> Rect {
        let h = self.skirt(steps) as i64;
        let o = self.owned(t);
        Rect::clamped(
            o.r1 as i64,
            o.r1 as i64 + h,
            o.c0 as i64 - h,
            o.c1 as i64 + h,
            self.rows,
            self.cols,
        )
    }

    /// Signed global (row, col) of tile `t`'s arena origin for an epoch
    /// of `steps`: the resident rect's corner before clamping, so data
    /// keeps a stable in-arena offset whether or not the grid edge
    /// clamps the skirt.
    pub fn tile_base(&self, t: usize, steps: usize) -> (i64, i64) {
        let h = self.skirt(steps) as i64;
        let o = self.owned(t);
        (o.r0 as i64 - h, o.c0 as i64 - h)
    }

    /// Uniform tile-arena shape for a whole run with at most `s_max` TB
    /// steps per epoch: tall/wide enough for the largest tile of the
    /// largest epoch, so fixed-shape (AOT-compiled) kernels serve every
    /// tile and epoch.
    pub fn uniform_buffer_dims(&self, s_max: usize) -> (usize, usize) {
        let pad = 2 * self.skirt(s_max);
        let max_rows =
            (0..self.tiles_y).map(|i| axis_owned(&self.row_bounds, i).len()).max().unwrap();
        let max_cols =
            (0..self.tiles_x).map(|j| axis_owned(&self.col_bounds, j).len()).max().unwrap();
        (max_rows + pad, max_cols + pad)
    }

    /// Bytes of one tile arena (input + output double buffer) at the
    /// uniform shape for `s_max`.
    pub fn arena_bytes(&self, s_max: usize) -> u64 {
        let (br, bc) = self.uniform_buffer_dims(s_max);
        2 * (br * bc * 4) as u64
    }

    /// Total region-share payload bytes one epoch of `steps` moves
    /// through the sharing buffer (each band counted once — the read
    /// side; the write side copies the same bytes). The closed form per
    /// interior tile is `(2h*(w + l) + 4h^2) * 4` bytes — O(perimeter)
    /// instead of the row-band scheme's O(cols) per boundary, which is
    /// the whole point of tiling.
    pub fn halo_bytes_per_epoch(&self, steps: usize) -> u64 {
        (0..self.n_tiles())
            .map(|t| {
                self.so2dr_read_north(t, steps).bytes_f32()
                    + self.so2dr_read_west(t, steps).bytes_f32()
            })
            .sum()
    }

    // ---------------------------------------------------------------
    // ResReu (skewed parallelogram) tile rects.
    //
    // The skewed scheme generalizes to tiles as a *product of two 1-D
    // skews*: every window, band, and writeback span is the product of
    // the per-axis ResReu formulas, with data flowing toward lower
    // indices along both axes (windows shift up and left by `r` per
    // step, so each tile reads the rows/cols its north/west neighbor
    // just vacated). Per TB step `s`, tile `(i, j)`:
    //
    // * reads its **west band** (time `s-1` data) from `(i, j-1)`:
    //   `2r` columns beside its shifted window, spanning its shifted
    //   row extent grown to the grid edge on edge rows;
    // * publishes its **south band** for `(i+1, j)` and **east band**
    //   for `(i, j+1)` — epoch-start-of-step data, extracted before its
    //   kernels overwrite it;
    // * reads its **north band** from `(i-1, j)`: `2r` rows across its
    //   *incoming* skirted column extent (corner cells included — the
    //   `2r x 2r` corner from `(i-1, j-1)` cascades west-then-south,
    //   mirroring the staged SO2DR corner rule).
    //
    // Reading west *before* publishing south keeps the cascade causal
    // in a single chunk-major sweep: by the time `(i, j)` publishes its
    // south band (which includes west-corner cells), it has already
    // pulled those cells from `(i, j-1)`.
    //
    // Degeneracy: `tiles_x == 1` makes the west/east bands empty and
    // every column span full-width, reproducing the 1-D ResReu plan
    // op-for-op; `tiles_y == 1` is its transpose.
    // ---------------------------------------------------------------

    /// HtoD rect under ResReu tiling: exactly the owned rect
    /// (intermediate halo data arrives through the region-sharing
    /// buffer, as in 1-D).
    pub fn resreu_htod(&self, t: usize) -> Rect {
        self.owned(t)
    }

    /// Per-axis skewed span at step `s` (1-based): `[a - s*r, b - s*r)`,
    /// the first chunk's lower edge pinned at the interior boundary and
    /// the last chunk's upper edge at `extent - r` — the 1-D
    /// [`Decomposition::resreu_window`] formula, per axis.
    fn resreu_axis_window(
        bounds: &[usize],
        extent: usize,
        parts: usize,
        i: usize,
        radius: usize,
        s: usize,
    ) -> RowSpan {
        let shift = (s * radius) as i64;
        let o = axis_owned(bounds, i);
        let r = radius as i64;
        let lo = if i == 0 { r } else { o.lo as i64 - shift };
        let hi = if i + 1 == parts { extent as i64 - r } else { o.hi as i64 - shift };
        RowSpan::clamped(lo.max(r), hi.min(extent as i64 - r), extent)
    }

    /// Compute window for tile `t` at TB step `s` (1-based): the product
    /// of the per-axis skewed windows, clamped to the Dirichlet interior.
    pub fn resreu_window(&self, t: usize, steps: usize, s: usize) -> Rect {
        assert!((1..=steps).contains(&s));
        let (i, j) = self.tile_rc(t);
        Rect::of_spans(
            Self::resreu_axis_window(&self.row_bounds, self.rows, self.tiles_y, i, self.radius, s),
            Self::resreu_axis_window(&self.col_bounds, self.cols, self.tiles_x, j, self.radius, s),
        )
    }

    /// Row extent of tile row `i`'s step-`s` working set *after* `u`
    /// skew shifts, grown to the grid edge on edge rows: the rows whose
    /// time `s-1` values tile `(i, j)` holds when step `s` runs.
    fn resreu_row_extent(&self, i: usize, u: usize) -> RowSpan {
        let shift = (u * self.radius) as i64;
        let o = axis_owned(&self.row_bounds, i);
        let lo = if i == 0 { 0 } else { o.lo as i64 - shift };
        let hi = if i + 1 == self.tiles_y { self.rows as i64 } else { o.hi as i64 - shift };
        RowSpan::clamped(lo, hi, self.rows)
    }

    /// Incoming skirted column extent of tile col `j` at step `s`: the
    /// columns tile `(i, j)`'s step-`s` reads can touch, grown to the
    /// grid edge on edge columns — `[a - s*r - r, b - (s-1)*r)`.
    fn resreu_col_extent_in(&self, j: usize, s: usize) -> RowSpan {
        let o = axis_owned(&self.col_bounds, j);
        let r = self.radius as i64;
        let s = s as i64;
        let lo = if j == 0 { 0 } else { o.lo as i64 - s * r - r };
        let hi = if j + 1 == self.tiles_x { self.cols as i64 } else { o.hi as i64 - (s - 1) * r };
        RowSpan::clamped(lo, hi, self.cols)
    }

    /// West band (time `s-1` data) tile `t` reads from `(i, j-1)`
    /// before step `s`: `2r` columns below its shifted window across
    /// its previous-step row extent. Empty for the first tile column.
    pub fn resreu_read_west(&self, t: usize, s: usize) -> Rect {
        let (i, j) = self.tile_rc(t);
        if j == 0 {
            return Rect::new(0, 0, 0, 0);
        }
        let a = self.col_bounds[j] as i64;
        let r = self.radius as i64;
        let si = s as i64;
        Rect::of_spans(
            self.resreu_row_extent(i, s - 1),
            RowSpan::clamped(a - si * r - r, a - (si - 1) * r, self.cols),
        )
    }

    /// East band tile `t` publishes for `(i, j+1)` before step `s` —
    /// by construction `write_east(i, j, s) == read_west(i, j+1, s)`.
    /// Empty for the last tile column.
    pub fn resreu_write_east(&self, t: usize, s: usize) -> Rect {
        let (i, j) = self.tile_rc(t);
        if j + 1 == self.tiles_x {
            return Rect::new(0, 0, 0, 0);
        }
        self.resreu_read_west(self.index(i, j + 1), s)
    }

    /// North band (time `s-1` data) tile `t` reads from `(i-1, j)`
    /// before step `s`: `2r` rows below its shifted window across its
    /// incoming skirted column extent (west corners included — they
    /// cascaded into `(i-1, j)` one step earlier). Empty for the first
    /// tile row.
    pub fn resreu_read_north(&self, t: usize, s: usize) -> Rect {
        let (i, j) = self.tile_rc(t);
        if i == 0 {
            return Rect::new(0, 0, 0, 0);
        }
        let a = self.row_bounds[i] as i64;
        let r = self.radius as i64;
        let si = s as i64;
        Rect::of_spans(
            RowSpan::clamped(a - si * r - r, a - (si - 1) * r, self.rows),
            self.resreu_col_extent_in(j, s),
        )
    }

    /// South band tile `t` publishes for `(i+1, j)` before step `s` —
    /// by construction `write_south(i, j, s) == read_north(i+1, j, s)`.
    /// Empty for the last tile row.
    pub fn resreu_write_south(&self, t: usize, s: usize) -> Rect {
        let (i, j) = self.tile_rc(t);
        if i + 1 == self.tiles_y {
            return Rect::new(0, 0, 0, 0);
        }
        self.resreu_read_north(self.index(i + 1, j), s)
    }

    /// Per-axis skew-shifted writeback span after an epoch of `steps`:
    /// `[a - h, b - h)`, the first chunk keeping the axis origin and
    /// the last its tail — the 1-D [`Decomposition::resreu_dtoh`]
    /// formula, per axis. The DtoH rects partition the grid.
    fn resreu_axis_dtoh(
        bounds: &[usize],
        extent: usize,
        parts: usize,
        i: usize,
        h: i64,
    ) -> RowSpan {
        let o = axis_owned(bounds, i);
        let lo = if i == 0 { 0 } else { o.lo as i64 - h };
        let hi = if i + 1 == parts { extent as i64 } else { o.hi as i64 - h };
        RowSpan::clamped(lo, hi, extent)
    }

    /// DtoH rect after a ResReu epoch of `steps`: the product of the
    /// per-axis skew-shifted spans — the rects partition the grid.
    pub fn resreu_dtoh(&self, t: usize, steps: usize) -> Rect {
        let h = self.skirt(steps) as i64;
        let (i, j) = self.tile_rc(t);
        Rect::of_spans(
            Self::resreu_axis_dtoh(&self.row_bounds, self.rows, self.tiles_y, i, h),
            Self::resreu_axis_dtoh(&self.col_bounds, self.cols, self.tiles_x, j, h),
        )
    }

    /// Rect of tile `t` valid at the current time step in its arena
    /// after an epoch of `steps` under `scheme`: the writeback rect.
    /// Settled rects partition the grid for both schemes.
    pub fn settled_for(&self, scheme: crate::chunking::Scheme, t: usize, steps: usize) -> Rect {
        match scheme {
            crate::chunking::Scheme::So2dr => self.owned(t),
            crate::chunking::Scheme::ResReu => self.resreu_dtoh(t, steps),
            crate::chunking::Scheme::InCore => Rect::new(0, self.rows, 0, self.cols),
        }
    }

    /// East column band tile `t` fetches at the start of a resident
    /// ResReu epoch: the previous epoch's windows shifted left by
    /// `h_prev`, so the right `[c1-h', c1)` strip of each settled row
    /// extent lives in tile `(i, j+1)`'s arena. Empty for the last tile
    /// column (its window's right edge does not shift).
    pub fn resreu_fetch_east(&self, t: usize, prev_steps: usize) -> Rect {
        let (i, j) = self.tile_rc(t);
        if j + 1 == self.tiles_x {
            return Rect::new(0, 0, 0, 0);
        }
        let h = self.skirt(prev_steps) as i64;
        let o = axis_owned(&self.col_bounds, j);
        Rect::of_spans(
            self.resreu_row_extent(i, prev_steps),
            RowSpan::clamped(o.hi as i64 - h, o.hi as i64, self.cols),
        )
    }

    /// South row band tile `t` fetches at the start of a resident
    /// ResReu epoch: the bottom `[r1-h', r1)` strip across its settled
    /// column extent (east corners included — they arrive at the
    /// publisher `(i+1, j)` through its *own* east fetch, which the
    /// pass structure orders first). Empty for the last tile row.
    pub fn resreu_fetch_south(&self, t: usize, prev_steps: usize) -> Rect {
        let (i, j) = self.tile_rc(t);
        if i + 1 == self.tiles_y {
            return Rect::new(0, 0, 0, 0);
        }
        let h = self.skirt(prev_steps) as i64;
        let o = axis_owned(&self.row_bounds, i);
        let c = axis_owned(&self.col_bounds, j);
        let clo = if j == 0 { 0 } else { c.lo as i64 - h };
        Rect::of_spans(
            RowSpan::clamped(o.hi as i64 - h, o.hi as i64, self.rows),
            RowSpan::clamped(clo, c.hi as i64, self.cols),
        )
    }

    /// Total region-share payload bytes one ResReu tile epoch of
    /// `steps` moves through the sharing buffer (read side counted
    /// once): the per-step west + north bands summed over all tiles
    /// and steps — O(perimeter) per tile per step.
    pub fn resreu_halo_bytes_per_epoch(&self, steps: usize) -> u64 {
        (1..=steps)
            .flat_map(|s| {
                (0..self.n_tiles()).map(move |t| (t, s))
            })
            .map(|(t, s)| {
                self.resreu_read_west(t, s).bytes_f32() + self.resreu_read_north(t, s).bytes_f32()
            })
            .sum()
    }

    // ---------------------------------------------------------------
    // Scheme-aware arena geometry. SO2DR tile arenas pad the owned
    // rect by the skirt on *all four* sides (trapezoids grow both
    // ways); ResReu arenas pad only below/left by `h + r` (windows
    // shift down-left and the final window still reads `r` cells
    // past itself), exactly as the 1-D `uniform_buffer_rows` /
    // `resident_base` pair distinguishes the schemes.
    // ---------------------------------------------------------------

    /// `(low, high)` per-axis arena padding for `scheme` at `steps`.
    fn axis_pads(&self, scheme: crate::chunking::Scheme, steps: usize) -> (usize, usize) {
        let h = self.skirt(steps);
        match scheme {
            crate::chunking::Scheme::So2dr => (h, h),
            crate::chunking::Scheme::ResReu => (h + self.radius, 0),
            crate::chunking::Scheme::InCore => (0, 0),
        }
    }

    /// Signed global (row, col) of tile `t`'s arena origin for an epoch
    /// of `steps` under `scheme`: the unclamped resident corner, so
    /// data keeps a stable in-arena offset whether or not the grid edge
    /// clamps the skirt. `tile_base` is the SO2DR specialization.
    pub fn tile_base_for(
        &self,
        scheme: crate::chunking::Scheme,
        t: usize,
        steps: usize,
    ) -> (i64, i64) {
        let (lo, _hi) = self.axis_pads(scheme, steps);
        let o = self.owned(t);
        (o.r0 as i64 - lo as i64, o.c0 as i64 - lo as i64)
    }

    /// Uniform tile-arena shape for a whole run of `scheme` with at
    /// most `s_max` TB steps per epoch. `uniform_buffer_dims` is the
    /// SO2DR specialization.
    pub fn uniform_buffer_dims_for(
        &self,
        scheme: crate::chunking::Scheme,
        s_max: usize,
    ) -> (usize, usize) {
        let (lo, hi) = self.axis_pads(scheme, s_max);
        let pad = lo + hi;
        let max_rows =
            (0..self.tiles_y).map(|i| axis_owned(&self.row_bounds, i).len()).max().unwrap();
        let max_cols =
            (0..self.tiles_x).map(|j| axis_owned(&self.col_bounds, j).len()).max().unwrap();
        (max_rows + pad, max_cols + pad)
    }

    /// Bytes of one tile arena (input + output double buffer) at the
    /// uniform shape for `scheme` and `s_max`.
    pub fn arena_bytes_for(&self, scheme: crate::chunking::Scheme, s_max: usize) -> u64 {
        let (br, bc) = self.uniform_buffer_dims_for(scheme, s_max);
        2 * (br * bc * 4) as u64
    }
}

/// Hierarchical tiling configuration: the one value that unifies the
/// `--chunks` / `--chunks-x` / `--chunks-y` CLI surface and the
/// planner's decomposition choice (modeled after kubecl's hierarchical
/// tiling scheme — one partition count per axis, with the degenerate
/// axis count 1 collapsing a level instead of switching code paths).
///
/// `tiles_x == 1` *is* the row-band decomposition: a `TilingConfig`
/// in rows mode builds a [`Decomposition`] whose plans are op-for-op
/// equal to the 1×N [`Decomposition2d`] plans, so every consumer can
/// carry a `TilingConfig` and lower it late.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct TilingConfig {
    /// Partition count along the row axis (the 1-D `--chunks` count).
    pub tiles_y: usize,
    /// Partition count along the column axis (1 = row bands).
    pub tiles_x: usize,
}

impl TilingConfig {
    /// Row-band mode: `d` bands, no column split.
    pub fn rows(d: usize) -> Self {
        Self { tiles_y: d, tiles_x: 1 }
    }

    /// Grid mode: `tiles_y x tiles_x` tiles.
    pub fn grid(tiles_y: usize, tiles_x: usize) -> Self {
        Self { tiles_y, tiles_x }
    }

    /// True when this tiling is the 1-D row-band decomposition.
    pub fn is_rows(&self) -> bool {
        self.tiles_x == 1
    }

    pub fn n_tiles(&self) -> usize {
        self.tiles_y * self.tiles_x
    }

    /// Build the 2-D decomposition this tiling describes.
    pub fn build_2d(&self, rows: usize, cols: usize, radius: usize) -> Result<Decomposition2d> {
        Decomposition2d::try_new(rows, cols, self.tiles_y, self.tiles_x, radius)
    }

    /// Build the 1-D row-band decomposition (rows mode only).
    pub fn build_rows(&self, rows: usize, cols: usize, radius: usize) -> Result<Decomposition> {
        if !self.is_rows() {
            bail!(
                "a {}x{} tiling is not a row-band decomposition",
                self.tiles_y,
                self.tiles_x
            );
        }
        Decomposition::try_new(rows, cols, self.tiles_y, radius)
    }
}

/// Heterogeneous per-device memory capacity caps, in bytes.
///
/// The capacity model was all-or-nothing with a single homogeneous cap
/// through PR 8; a fleet of mixed devices (the `serve` scheduler's
/// input) needs one limit *per device slot*. `None` in a slot means
/// that device is uncapped. Constructed either uniformly (the legacy
/// single-cap surface delegates through [`DeviceCaps::uniform`]) or
/// per-device ([`DeviceCaps::per_device`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DeviceCaps {
    caps: Vec<Option<u64>>,
}

impl DeviceCaps {
    /// The homogeneous model: every one of `n_devices` slots gets the
    /// same cap (`None` = uncapped everywhere).
    pub fn uniform(n_devices: usize, cap: Option<u64>) -> Self {
        Self { caps: vec![cap; n_devices] }
    }

    /// One explicit cap per device slot. Panics on an empty fleet.
    pub fn per_device(caps: Vec<Option<u64>>) -> Self {
        assert!(!caps.is_empty(), "a device-cap table needs at least one device");
        Self { caps }
    }

    pub fn n_devices(&self) -> usize {
        self.caps.len()
    }

    /// Cap of device `dev` (`None` = uncapped).
    pub fn cap(&self, dev: usize) -> Option<u64> {
        self.caps[dev]
    }

    /// Accept/reject verdict for one device: does `demand` bytes fit
    /// under device `dev`'s cap?
    pub fn admits(&self, dev: usize, demand: u64) -> bool {
        match self.caps[dev] {
            None => true,
            Some(cap) => demand <= cap,
        }
    }

    /// Per-device accept/reject table for a demand vector (one entry
    /// per device slot). Panics when the vector length disagrees with
    /// the fleet size — a demand computed for a different assignment is
    /// a caller bug, not a reject.
    pub fn admit_table(&self, demand: &[u64]) -> Vec<bool> {
        assert_eq!(
            demand.len(),
            self.caps.len(),
            "demand vector is per-device and must match the cap table"
        );
        demand.iter().enumerate().map(|(dev, &need)| self.admits(dev, need)).collect()
    }

    /// All-devices verdict: every entry of [`Self::admit_table`] accepts.
    pub fn admits_all(&self, demand: &[u64]) -> bool {
        self.admit_table(demand).iter().all(|&ok| ok)
    }
}

/// Assignment of chunks to devices for a sharded (multi-GPU) run.
///
/// Chunks are mapped to devices in contiguous near-equal blocks, so the
/// only inter-device halo traffic is at the `n_devices - 1` block
/// boundaries — every interior region share stays a cheap on-device copy,
/// and a boundary share becomes a peer-to-peer (`D2D`) link transfer.
/// Devices are modeled with homogeneous bandwidths; memory capacity may
/// differ per device ([`DeviceCaps`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DeviceAssignment {
    n_devices: usize,
    /// `of_chunk[i]` = device owning chunk `i` (non-decreasing).
    of_chunk: Vec<usize>,
}

impl DeviceAssignment {
    /// Contiguous near-equal split of `n_chunks` chunks over `n_devices`
    /// devices. Panics if `n_devices == 0` or `n_devices > n_chunks`.
    pub fn contiguous(n_chunks: usize, n_devices: usize) -> Self {
        assert!(
            n_devices > 0 && n_devices <= n_chunks,
            "invalid device count {n_devices} for {n_chunks} chunks"
        );
        let parts = split_range(0, n_chunks, n_devices);
        assert_eq!(parts.len(), n_devices);
        let mut of_chunk = vec![0usize; n_chunks];
        for (dev, &(a, b)) in parts.iter().enumerate() {
            for item in of_chunk.iter_mut().take(b).skip(a) {
                *item = dev;
            }
        }
        Self { n_devices, of_chunk }
    }

    /// Everything on one device (the seed's original behavior).
    pub fn single(n_chunks: usize) -> Self {
        Self::contiguous(n_chunks, 1)
    }

    /// Block-grid assignment for a `tiles_y x tiles_x` tile grid: whole
    /// tile *rows* are dealt to devices in contiguous near-equal blocks,
    /// so a tile row is never split across devices — every west/east
    /// band stays an on-device copy and only the `n_devices - 1` row
    /// seams carry `D2D` link traffic (O(row-perimeter) per seam,
    /// instead of cutting through the per-step column cascade). Because
    /// tiles are row-major, the resulting chunk→device map is still
    /// non-decreasing and contiguous, so every contiguous-range consumer
    /// ([`Self::chunks_on`], the executor's worker partitions) works
    /// unchanged. With `tiles_x == 1` this *is* [`Self::contiguous`].
    /// Panics if `n_devices == 0` or `n_devices > tiles_y`.
    pub fn block_grid(tiles_y: usize, tiles_x: usize, n_devices: usize) -> Self {
        assert!(
            n_devices > 0 && n_devices <= tiles_y,
            "invalid device count {n_devices} for {tiles_y} tile rows \
             (block-grid assignment deals whole rows)"
        );
        let parts = split_range(0, tiles_y, n_devices);
        assert_eq!(parts.len(), n_devices);
        let mut of_chunk = vec![0usize; tiles_y * tiles_x];
        for (dev, &(a, b)) in parts.iter().enumerate() {
            for item in of_chunk.iter_mut().take(b * tiles_x).skip(a * tiles_x) {
                *item = dev;
            }
        }
        Self { n_devices, of_chunk }
    }

    /// The tile→device map every tile entry point (real-numerics driver
    /// and DES pricing) shares, so the two executions agree on where
    /// band traffic crosses devices: [`Self::block_grid`] whenever the
    /// device count divides into whole tile rows, contiguous row-major
    /// otherwise.
    pub fn for_tiles(dc: &Decomposition2d, n_devices: usize) -> Self {
        if n_devices > 0 && n_devices <= dc.tiles_y() {
            Self::block_grid(dc.tiles_y(), dc.tiles_x(), n_devices)
        } else {
            Self::contiguous(dc.n_tiles(), n_devices)
        }
    }

    pub fn n_devices(&self) -> usize {
        self.n_devices
    }

    pub fn n_chunks(&self) -> usize {
        self.of_chunk.len()
    }

    /// Device owning chunk `i`.
    pub fn device_of(&self, chunk: usize) -> usize {
        self.of_chunk[chunk]
    }

    /// Chunk index range owned by device `dev`.
    pub fn chunks_on(&self, dev: usize) -> std::ops::Range<usize> {
        let lo = self.of_chunk.iter().position(|&d| d == dev).unwrap_or(0);
        let hi = self.of_chunk.iter().rposition(|&d| d == dev).map(|p| p + 1).unwrap_or(0);
        lo..hi
    }

    /// True when chunks `i` and `i + 1` live on different devices, i.e.
    /// their region share must cross the inter-device link.
    pub fn crosses_boundary(&self, i: usize) -> bool {
        i + 1 < self.of_chunk.len() && self.of_chunk[i] != self.of_chunk[i + 1]
    }

    /// Per-device capacity accounting: device-memory bytes demanded on
    /// each device when up to `n_strm` chunk pipelines are in flight per
    /// device, each double buffered, during an epoch of `steps` —
    /// the multi-device analog of the §IV-C memory constraint
    /// `(D_chk + W_halo*S_TB) * N_strm * N_buf <= C_dmem`, now checked
    /// per shard instead of globally.
    pub fn device_memory_demand(
        &self,
        dc: &Decomposition,
        steps: usize,
        n_strm: usize,
        kind: StencilKind,
    ) -> Vec<u64> {
        (0..self.n_devices)
            .map(|dev| {
                let chunks = self.chunks_on(dev);
                let live = n_strm.max(1).min(chunks.len().max(1)) as u64;
                let worst = chunks
                    .map(|i| dc.resident_bytes(i, steps, kind))
                    .max()
                    .unwrap_or(0);
                live * 2 * worst
            })
            .collect()
    }

    /// Device-memory demand (bytes) of a resident-model run on device
    /// `dev`: one arena per chunk assigned to the device, plus a
    /// region-sharing slack of `12 * h_max` rows per chunk.
    ///
    /// The arena term charges *every* chunk — not just pinned ones —
    /// because resident epochs execute in two phases (all arrivals and
    /// publishes before any fetch/kernel/eviction), so at the epoch
    /// boundary every chunk's arena on the device is live at once:
    /// spilled chunks re-allocate in phase A and only release at their
    /// phase-B `Evict`. Spilling therefore saves host traffic modeling,
    /// not peak arena footprint, in the current execution model;
    /// staggering spilled arrivals to reclaim that peak is a ROADMAP
    /// follow-on. The slack dominates the worst case of either scheme:
    /// a chunk-epoch's sharing allocations (its region writes,
    /// publishes, and incoming link copies) total at most `4 * h` rows
    /// for SO2DR and `6 * h` for ResReu, live until their consumer
    /// retires, and at most two adjacent epochs' regions can overlap on
    /// a device. The DES's observed peak never exceeds this bound,
    /// which is what lets the planner promise `capacity_exceeded` won't
    /// fire on accepted plans.
    pub fn resident_memory_demand(
        &self,
        dc: &Decomposition,
        dev: usize,
        buf_rows: usize,
        h_max: usize,
    ) -> u64 {
        let nc = self.chunks_on(dev).len() as u64;
        let rs_slack = nc * 12 * (h_max * dc.cols() * 4) as u64;
        nc * dc.arena_bytes(buf_rows) + rs_slack
    }

    /// Device-memory demand (bytes) of a resident-tile run on device
    /// `dev`: one tile arena per tile assigned to the device at the
    /// uniform `s_max` shape, plus a region-sharing slack of 16 bands
    /// of `h_max x max-skirted-side` cells per tile.
    ///
    /// The arena term charges *every* tile — the pass-structured epoch
    /// (all arrivals and publishes before any tile's retirement) holds
    /// every tile arena live at the epoch boundary, exactly as in the
    /// 1-D model above. The slack dominates the worst case: a
    /// tile-epoch allocates at most 4 published bands plus 4 incoming
    /// link copies, each at most `h x (max side + 2h)` cells, live
    /// until their consumer retires, and at most two adjacent epochs'
    /// bands can overlap on a device — 16 bands per tile with margin.
    /// The DES's observed peak never exceeds this bound, which is what
    /// lets the tile planner promise `capacity_exceeded` won't fire on
    /// accepted plans.
    pub fn resident_tile_memory_demand(
        &self,
        dc: &Decomposition2d,
        dev: usize,
        s_max: usize,
    ) -> u64 {
        let nc = self.chunks_on(dev).len() as u64;
        let (br, bc) = dc.uniform_buffer_dims(s_max);
        let band = (dc.skirt(s_max) * br.max(bc) * 4) as u64;
        nc * dc.arena_bytes(s_max) + nc * 16 * band
    }

    /// Per-device pinned-tile counts under a uniform `cap` — the
    /// homogeneous surface over [`Self::resident_tile_keep_counts_caps`]
    /// (`None` caps nothing, keep all).
    pub fn resident_tile_keep_counts(
        &self,
        dc: &Decomposition2d,
        s_max: usize,
        cap: Option<u64>,
    ) -> Vec<usize> {
        self.resident_tile_keep_counts_caps(dc, s_max, &DeviceCaps::uniform(self.n_devices, cap))
    }

    /// Per-device pinned-tile counts under heterogeneous caps and
    /// [`Self::resident_tile_memory_demand`]: the same all-or-nothing
    /// rule as [`Self::resident_keep_counts_caps`] (spilling cannot
    /// lower the modeled epoch-boundary peak, only pinning-vs-not
    /// changes host traffic), decided per device against *its own* cap.
    pub fn resident_tile_keep_counts_caps(
        &self,
        dc: &Decomposition2d,
        s_max: usize,
        caps: &DeviceCaps,
    ) -> Vec<usize> {
        assert_eq!(caps.n_devices(), self.n_devices, "cap table must match the fleet");
        (0..self.n_devices)
            .map(|dev| {
                let nc = self.chunks_on(dev).len();
                if caps.admits(dev, self.resident_tile_memory_demand(dc, dev, s_max)) {
                    nc
                } else {
                    0
                }
            })
            .collect()
    }

    /// Per-device pinned-chunk counts under a uniform `cap` — the
    /// homogeneous surface over [`Self::resident_keep_counts_caps`]
    /// (`None` caps nothing, keep all).
    pub fn resident_keep_counts(
        &self,
        dc: &Decomposition,
        buf_rows: usize,
        h_max: usize,
        cap: Option<u64>,
    ) -> Vec<usize> {
        self.resident_keep_counts_caps(
            dc,
            buf_rows,
            h_max,
            &DeviceCaps::uniform(self.n_devices, cap),
        )
    }

    /// Per-device pinned-chunk counts under heterogeneous caps and
    /// [`Self::resident_memory_demand`]. Because the epoch-boundary
    /// footprint is the same whether chunks pin or spill (see above),
    /// the decision is all-or-nothing per device: pin everything when
    /// the device's demand fits *its own* cap (pinning only removes
    /// host traffic), else pin nothing and spill every epoch. A mixed
    /// fleet therefore pins on its big devices and spills on its small
    /// ones — the accept/reject split the `serve` packer leans on.
    pub fn resident_keep_counts_caps(
        &self,
        dc: &Decomposition,
        buf_rows: usize,
        h_max: usize,
        caps: &DeviceCaps,
    ) -> Vec<usize> {
        assert_eq!(caps.n_devices(), self.n_devices, "cap table must match the fleet");
        (0..self.n_devices)
            .map(|dev| {
                let nc = self.chunks_on(dev).len();
                if caps.admits(dev, self.resident_memory_demand(dc, dev, buf_rows, h_max)) {
                    nc
                } else {
                    0
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dec(rows: usize, d: usize, r: usize) -> Decomposition {
        Decomposition::new(rows, 64, d, r)
    }

    #[test]
    fn bounds_partition_rows() {
        let dc = dec(103, 4, 1);
        let mut cur = 0;
        for i in 0..4 {
            let o = dc.owned(i);
            assert_eq!(o.lo, cur);
            cur = o.hi;
        }
        assert_eq!(cur, 103);
    }

    #[test]
    fn so2dr_htod_partitions_grid() {
        for (rows, d, r, steps) in [(120, 4, 1, 8), (200, 5, 2, 4), (96, 3, 4, 2)] {
            let dc = dec(rows, d, r);
            dc.check(steps);
            let mut cur = 0;
            for i in 0..d {
                let t = dc.so2dr_htod(i, steps);
                assert_eq!(t.lo, cur, "chunk {i}");
                cur = t.hi;
            }
            assert_eq!(cur, rows);
        }
    }

    #[test]
    fn so2dr_rs_pairs_match() {
        let dc = dec(160, 4, 2);
        let steps = 6;
        for i in 1..4 {
            assert_eq!(dc.so2dr_rs_read(i, steps), dc.so2dr_rs_write(i - 1, steps));
        }
        assert!(dc.so2dr_rs_read(0, steps).is_empty());
        assert!(dc.so2dr_rs_write(3, steps).is_empty());
    }

    #[test]
    fn so2dr_window_shrinks_to_owned() {
        let dc = dec(160, 4, 2);
        let steps = 6;
        // Final step's window == owned rows (clamped to interior).
        for i in 0..4 {
            let w = dc.so2dr_window(i, steps, steps);
            let o = dc.owned(i);
            let expect = RowSpan::clamped(
                o.lo.max(2) as i64,
                o.hi.min(158) as i64,
                160,
            );
            assert_eq!(w, expect, "chunk {i}");
        }
        // Windows grow toward earlier steps.
        for s in 1..steps {
            assert!(dc.so2dr_window(1, steps, s).len() > dc.so2dr_window(1, steps, s + 1).len());
        }
    }

    #[test]
    fn so2dr_window_within_resident_minus_r() {
        let dc = dec(160, 4, 2);
        let steps = 6;
        for i in 0..4 {
            let res = dc.so2dr_resident(i, steps);
            for s in 1..=steps {
                let w = dc.so2dr_window(i, steps, s);
                assert!(w.lo >= res.lo + 2 || (res.lo == 0 && w.lo >= 2));
                assert!(w.hi + 2 <= res.hi || (res.hi == 160 && w.hi <= 158));
            }
        }
    }

    #[test]
    fn so2dr_redundancy_closed_form() {
        let dc = dec(400, 4, 1);
        let steps = 10;
        for s in 1..=steps {
            // Interior boundaries, no clamping at this size:
            // overlap per boundary = 2*(steps-s)*r.
            assert_eq!(dc.so2dr_redundant_rows(steps, s), 3 * 2 * (steps - s));
        }
    }

    #[test]
    fn resreu_windows_tile_interior() {
        let dc = dec(200, 4, 2);
        let steps = 5;
        dc.check(steps);
        for s in 1..=steps {
            let mut cur = 2; // interior starts at r
            for i in 0..4 {
                let w = dc.resreu_window(i, steps, s);
                assert_eq!(w.lo, cur, "step {s} chunk {i}");
                cur = w.hi;
            }
            assert_eq!(cur, 198); // rows - r
        }
    }

    #[test]
    fn resreu_rs_pairs_match() {
        let dc = dec(200, 4, 2);
        for s in 1..=5 {
            for i in 1..4 {
                assert_eq!(dc.resreu_rs_read(i, s), dc.resreu_rs_write(i - 1, s));
                assert_eq!(dc.resreu_rs_read(i, s).len(), 2 * 2); // 2r rows
            }
        }
    }

    #[test]
    fn resreu_dtoh_partitions_grid() {
        let dc = dec(200, 4, 2);
        let steps = 5;
        let mut cur = 0;
        for i in 0..4 {
            let t = dc.resreu_dtoh(i, steps);
            assert_eq!(t.lo, cur);
            cur = t.hi;
        }
        assert_eq!(cur, 200);
    }

    #[test]
    fn resreu_window_needs_only_resident_rows() {
        let dc = dec(200, 4, 2);
        let steps = 5;
        for i in 0..4 {
            let res = dc.resreu_resident(i, steps);
            for s in 1..=steps {
                let w = dc.resreu_window(i, steps, s);
                // Reads beyond the lower edge are satisfied by RS reads
                // of 2r rows just below w.lo, which land inside resident.
                let rs = dc.resreu_rs_read(i, s);
                if i > 0 {
                    assert!(res.contains_span(&rs), "chunk {i} step {s}: rs {rs} vs res {res}");
                }
                assert!(w.hi + 2 <= res.hi + 2 + 1, "upper edge inside resident + r");
            }
        }
    }

    #[test]
    fn feasibility_boundary() {
        let dc = dec(100, 4, 1); // chunks of 25 rows
        assert!(dc.feasible(24));
        assert!(!dc.feasible(25));
    }

    #[test]
    fn paper_model_bytes() {
        let dc = Decomposition::new(1000, 500, 4, 2);
        assert_eq!(dc.chunk_bytes(0), 250 * 500 * 4);
        assert_eq!(dc.halo_bytes_per_step(), 2 * 2 * 500 * 4);
        assert_eq!(
            dc.resident_bytes(0, 10, StencilKind::Box { radius: 2 }),
            250 * 500 * 4 + 10 * 2 * 2 * 500 * 4
        );
    }

    #[test]
    fn device_assignment_contiguous_blocks() {
        let devs = DeviceAssignment::contiguous(8, 4);
        assert_eq!(devs.n_devices(), 4);
        assert_eq!(devs.n_chunks(), 8);
        for i in 0..8 {
            assert_eq!(devs.device_of(i), i / 2);
        }
        assert_eq!(devs.chunks_on(0), 0..2);
        assert_eq!(devs.chunks_on(3), 6..8);
        // Boundaries exactly between blocks.
        let boundaries: Vec<usize> = (0..7).filter(|&i| devs.crosses_boundary(i)).collect();
        assert_eq!(boundaries, vec![1, 3, 5]);
    }

    #[test]
    fn device_assignment_uneven_split() {
        let devs = DeviceAssignment::contiguous(5, 2);
        // Non-decreasing, both devices non-empty, sizes differ by <= 1.
        let on0 = devs.chunks_on(0).len();
        let on1 = devs.chunks_on(1).len();
        assert_eq!(on0 + on1, 5);
        assert!(on0.abs_diff(on1) <= 1);
        for i in 1..5 {
            assert!(devs.device_of(i) >= devs.device_of(i - 1));
        }
    }

    #[test]
    fn single_device_has_no_boundaries() {
        let devs = DeviceAssignment::single(6);
        assert_eq!(devs.n_devices(), 1);
        assert!((0..6).all(|i| !devs.crosses_boundary(i)));
        assert_eq!(devs.chunks_on(0), 0..6);
    }

    #[test]
    fn device_memory_demand_shrinks_with_more_devices() {
        let dc = Decomposition::new(960, 256, 8, 1);
        let kind = StencilKind::Box { radius: 1 };
        let one = DeviceAssignment::single(8).device_memory_demand(&dc, 8, 3, kind);
        let four = DeviceAssignment::contiguous(8, 4).device_memory_demand(&dc, 8, 3, kind);
        assert_eq!(one.len(), 1);
        assert_eq!(four.len(), 4);
        // Fewer in-flight pipelines per shard -> lower per-device demand.
        assert!(four.iter().max().unwrap() <= &one[0]);
    }

    #[test]
    #[should_panic(expected = "invalid device count")]
    fn more_devices_than_chunks_rejected() {
        DeviceAssignment::contiguous(2, 3);
    }

    #[test]
    fn settled_spans_partition_grid() {
        use crate::chunking::Scheme;
        let dc = dec(200, 4, 2);
        for (scheme, steps) in [(Scheme::So2dr, 6), (Scheme::ResReu, 5)] {
            let mut cur = 0;
            for i in 0..4 {
                let s = dc.settled(scheme, i, steps);
                assert_eq!(s.lo, cur, "{scheme:?} chunk {i}");
                cur = s.hi;
            }
            assert_eq!(cur, 200, "{scheme:?}");
        }
    }

    #[test]
    fn so2dr_fetch_spans_come_from_neighbor_settled() {
        use crate::chunking::Scheme;
        let dc = dec(200, 4, 2);
        let steps = 6;
        for i in 0..4 {
            let low = dc.so2dr_fetch_low(i, steps);
            let high = dc.so2dr_fetch_high(i, steps);
            if i == 0 {
                assert!(low.is_empty(), "chunk 0 has no lower neighbor");
            } else {
                assert_eq!(low.len(), dc.skirt(steps));
                assert!(dc.settled(Scheme::So2dr, i - 1, steps).contains_span(&low));
            }
            if i + 1 == 4 {
                assert!(high.is_empty(), "last chunk has no upper neighbor");
            } else {
                assert_eq!(high.len(), dc.skirt(steps));
                assert!(dc.settled(Scheme::So2dr, i + 1, steps).contains_span(&high));
            }
            // Settled + fetches cover the epoch's resident requirement.
            let covered = low.hull(&dc.owned(i)).hull(&high);
            assert_eq!(covered, dc.so2dr_resident(i, steps), "chunk {i}");
        }
    }

    #[test]
    fn resreu_fetch_spans_come_from_upper_neighbor_settled() {
        use crate::chunking::Scheme;
        let dc = dec(200, 4, 2);
        let prev_steps = 5;
        for i in 0..4 {
            let f = dc.resreu_fetch(i, prev_steps);
            if i + 1 == 4 {
                assert!(f.is_empty());
                continue;
            }
            assert_eq!(f.len(), dc.skirt(prev_steps));
            assert!(dc.settled(Scheme::ResReu, i + 1, prev_steps).contains_span(&f));
            // Own settled + fetch covers the owned epoch-start span.
            let s = dc.settled(Scheme::ResReu, i, prev_steps);
            assert!(s.hull(&f).contains_span(&dc.owned(i)), "chunk {i}");
        }
    }

    #[test]
    fn resident_keep_counts_scale_with_capacity() {
        let dc = Decomposition::new(960, 256, 8, 1);
        let devs = DeviceAssignment::contiguous(8, 2);
        let buf_rows = dc.uniform_buffer_rows(crate::chunking::Scheme::So2dr, 8);
        let none = devs.resident_keep_counts(&dc, buf_rows, 8, Some(1));
        let all = devs.resident_keep_counts(&dc, buf_rows, 8, None);
        let huge = devs.resident_keep_counts(&dc, buf_rows, 8, Some(u64::MAX));
        assert_eq!(none, vec![0, 0], "1-byte cap pins nothing");
        assert_eq!(all, vec![4, 4], "uncapped pins every chunk");
        assert_eq!(huge, all);
    }

    #[test]
    fn resident_demand_charges_every_chunk_arena() {
        // The two-phase epoch boundary holds every chunk's arena at once
        // (spilled chunks re-arrive in phase A and only evict in phase
        // B), so demand must charge nc arenas — spilling cannot lower
        // the modeled peak, only pinning-vs-not changes host traffic.
        let dc = Decomposition::new(960, 256, 8, 1);
        let devs = DeviceAssignment::contiguous(8, 2);
        let buf_rows = dc.uniform_buffer_rows(crate::chunking::Scheme::So2dr, 8);
        let nc = 4u64; // chunks per device
        let demand = devs.resident_memory_demand(&dc, 0, buf_rows, 8);
        let slack = nc * 12 * (8 * 256 * 4) as u64;
        assert_eq!(demand, nc * dc.arena_bytes(buf_rows) + slack);
        // A capacity exactly at the demand pins everything; one byte
        // less pins nothing (all-or-nothing per device).
        assert_eq!(devs.resident_keep_counts(&dc, buf_rows, 8, Some(demand)), vec![4, 4]);
        assert_eq!(
            devs.resident_keep_counts(&dc, buf_rows, 8, Some(demand - 1)),
            vec![0, 0]
        );
    }

    /// Accept/reject table for heterogeneous per-device caps: every
    /// (cap table, expected keep counts) row exercises a distinct mix of
    /// uncapped, exactly-at-demand, and one-byte-short device slots. The
    /// decision is per device against its own cap — a mixed fleet pins
    /// on its big devices and spills on its small ones.
    #[test]
    fn hetero_caps_accept_reject_table() {
        let dc = Decomposition::new(960, 256, 8, 1);
        let devs = DeviceAssignment::contiguous(8, 2);
        let buf_rows = dc.uniform_buffer_rows(crate::chunking::Scheme::So2dr, 8);
        let demand: Vec<u64> =
            (0..2).map(|dev| devs.resident_memory_demand(&dc, dev, buf_rows, 8)).collect();
        let cases: &[(Vec<Option<u64>>, Vec<usize>)] = &[
            // Uniform uncapped / tiny, via the hetero surface.
            (vec![None, None], vec![4, 4]),
            (vec![Some(1), Some(1)], vec![0, 0]),
            // Exactly at demand accepts; one byte short rejects.
            (vec![Some(demand[0]), Some(demand[1])], vec![4, 4]),
            (vec![Some(demand[0] - 1), Some(demand[1] - 1)], vec![0, 0]),
            // Mixed fleets: each device decided independently.
            (vec![Some(demand[0]), Some(demand[1] - 1)], vec![4, 0]),
            (vec![Some(demand[0] - 1), Some(demand[1])], vec![0, 4]),
            (vec![None, Some(1)], vec![4, 0]),
            (vec![Some(1), None], vec![0, 4]),
        ];
        for (caps, want) in cases {
            let table = DeviceCaps::per_device(caps.clone());
            assert_eq!(
                devs.resident_keep_counts_caps(&dc, buf_rows, 8, &table),
                *want,
                "caps {caps:?}"
            );
        }
        // The homogeneous surface is the uniform special case of the
        // heterogeneous one — the two cannot drift.
        for cap in [None, Some(1), Some(demand[0]), Some(u64::MAX)] {
            assert_eq!(
                devs.resident_keep_counts(&dc, buf_rows, 8, cap),
                devs.resident_keep_counts_caps(&dc, buf_rows, 8, &DeviceCaps::uniform(2, cap)),
                "cap {cap:?}"
            );
        }
    }

    /// [`DeviceCaps`] admission verdicts: the per-device accept/reject
    /// table and the all-devices verdict the serve packer uses.
    #[test]
    fn device_caps_admit_table() {
        let caps = DeviceCaps::per_device(vec![Some(100), Some(50), None]);
        assert_eq!(caps.n_devices(), 3);
        assert_eq!(caps.admit_table(&[100, 50, u64::MAX]), vec![true, true, true]);
        assert_eq!(caps.admit_table(&[101, 50, 7]), vec![false, true, true]);
        assert_eq!(caps.admit_table(&[100, 51, 7]), vec![true, false, true]);
        assert!(caps.admits_all(&[100, 50, 12]));
        assert!(!caps.admits_all(&[100, 51, 12]));
        assert!(caps.admits(2, u64::MAX), "an uncapped slot admits anything");
        assert_eq!(DeviceCaps::uniform(2, Some(9)).admit_table(&[9, 10]), vec![true, false]);
    }

    #[test]
    #[should_panic(expected = "must match")]
    fn device_caps_reject_mismatched_demand_vector() {
        DeviceCaps::per_device(vec![Some(1), Some(2)]).admit_table(&[1, 2, 3]);
    }

    #[test]
    fn uniform_buffer_rows_cover_every_epoch_span() {
        use crate::chunking::Scheme;
        let dc = dec(200, 4, 2);
        let s_max = 6;
        for scheme in [Scheme::So2dr, Scheme::ResReu] {
            let rows = dc.uniform_buffer_rows(scheme, s_max);
            for i in 0..4 {
                let base = dc.resident_base(scheme, s_max, i);
                for steps in 1..=s_max {
                    let span = match scheme {
                        Scheme::So2dr => dc.so2dr_resident(i, steps),
                        _ => dc.resreu_resident(i, steps),
                    };
                    assert!(span.lo as i64 >= base, "{scheme:?} chunk {i} steps {steps}");
                    assert!(
                        span.hi as i64 <= base + rows as i64,
                        "{scheme:?} chunk {i} steps {steps}"
                    );
                }
            }
        }
    }
}

#[cfg(test)]
mod ctor_tests {
    use super::*;

    /// Accept/reject table over both validated constructors (the shared
    /// per-axis error path), mirroring the PR 3 config tables: every
    /// rejection must name the violated constraint.
    #[test]
    fn constructor_acceptance_table_1d() {
        let accept: &[(usize, usize, usize, usize)] = &[
            (100, 64, 4, 1),
            (100, 64, 100, 1), // d == rows: every chunk owns one row
            (7, 3, 7, 1),
            (1000, 9, 4, 4),
        ];
        for &(rows, cols, d, r) in accept {
            assert!(
                Decomposition::try_new(rows, cols, d, r).is_ok(),
                "({rows},{cols},{d},{r}) rejected"
            );
        }
        let reject: &[(usize, usize, usize, usize, &str)] = &[
            (100, 64, 0, 1, "chunk count"),
            (100, 64, 101, 1, "exceeds"),
            (100, 64, 4, 0, "radius"),
            (2, 64, 1, 1, "rows extent"),  // rows <= 2r
            (100, 2, 4, 1, "cols extent"), // cols <= 2r
            (100, 8, 4, 4, "cols extent"),
            (0, 64, 1, 1, "chunk count"),  // 1 > 0 rows
        ];
        for &(rows, cols, d, r, needle) in reject {
            let err = Decomposition::try_new(rows, cols, d, r)
                .expect_err(&format!("({rows},{cols},{d},{r}) accepted"));
            assert!(
                err.to_string().contains(needle),
                "({rows},{cols},{d},{r}): {err} missing {needle:?}"
            );
        }
    }

    #[test]
    fn constructor_acceptance_table_2d() {
        let accept: &[(usize, usize, usize, usize, usize)] = &[
            (100, 100, 2, 2, 1),
            (100, 60, 1, 4, 2),
            (60, 100, 4, 1, 2),
            (10, 10, 10, 10, 1), // one cell per tile
        ];
        for &(rows, cols, ty, tx, r) in accept {
            assert!(
                Decomposition2d::try_new(rows, cols, ty, tx, r).is_ok(),
                "({rows},{cols},{ty}x{tx},{r}) rejected"
            );
        }
        let reject: &[(usize, usize, usize, usize, usize, &str)] = &[
            (100, 100, 0, 2, 1, "chunk count"),
            (100, 100, 2, 0, 1, "chunk count"),
            (100, 100, 101, 2, 1, "exceeds"),
            (100, 100, 2, 101, 1, "exceeds"),
            (100, 100, 2, 2, 0, "radius"),
            (4, 100, 2, 2, 2, "rows extent"),
            (100, 4, 2, 2, 2, "cols extent"),
        ];
        for &(rows, cols, ty, tx, r, needle) in reject {
            let err = Decomposition2d::try_new(rows, cols, ty, tx, r)
                .expect_err(&format!("({rows},{cols},{ty}x{tx},{r}) accepted"));
            assert!(
                err.to_string().contains(needle),
                "({rows},{cols},{ty}x{tx},{r}): {err} missing {needle:?}"
            );
        }
    }

    #[test]
    fn new_panics_with_the_validated_message() {
        let got = std::panic::catch_unwind(|| Decomposition::new(100, 64, 0, 1));
        let msg = *got.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("invalid decomposition"), "{msg}");
        assert!(msg.contains("chunk count"), "{msg}");
    }

    /// Degenerate case `d == rows`: constructible (one row per chunk),
    /// but no epoch is feasible — the skirt constraint needs
    /// `steps*r + r <= 1`, impossible for positive radius and steps.
    #[test]
    fn one_row_chunks_are_constructible_but_never_feasible() {
        let dc = Decomposition::new(8, 16, 8, 1);
        assert_eq!(dc.min_chunk_rows(), 1);
        for i in 0..8 {
            assert_eq!(dc.owned(i).len(), 1);
        }
        for steps in 1..4 {
            assert!(!dc.feasible(steps), "steps={steps}");
        }
    }

    /// Degenerate boundary: a chunk exactly as tall as the skirt is
    /// infeasible (the Dirichlet tightening needs one extra radius);
    /// skirt + radius tall is the exact feasibility edge.
    #[test]
    fn chunk_height_equal_to_skirt_is_the_infeasible_edge() {
        let (radius, steps) = (2usize, 3usize);
        let h = steps * radius; // 6
        let at_skirt = Decomposition::new(4 * h, 32, 4, radius);
        assert_eq!(at_skirt.min_chunk_rows(), h);
        assert!(!at_skirt.feasible(steps), "chunk == skirt must be infeasible");
        let at_edge = Decomposition::new(4 * (h + radius), 32, 4, radius);
        assert!(at_edge.feasible(steps), "chunk == skirt + r is exactly feasible");
        assert!(!at_edge.feasible(steps + 1));
    }

    /// The same two degenerate shapes along the 2-D axes.
    #[test]
    fn tile_degenerate_feasibility_edges() {
        let (radius, steps) = (1usize, 4usize);
        let h = steps * radius;
        // One-cell tiles: constructible, never feasible.
        let unit = Decomposition2d::try_new(6, 6, 6, 6, 1).unwrap();
        assert_eq!((unit.min_tile_rows(), unit.min_tile_cols()), (1, 1));
        assert!(!unit.feasible(1));
        // Tile side equal to the skirt: infeasible; skirt + r: feasible.
        let at_skirt = Decomposition2d::try_new(2 * h, 2 * h, 2, 2, radius).unwrap();
        assert!(!at_skirt.feasible(steps));
        let edge = Decomposition2d::try_new(2 * (h + radius), 2 * (h + radius), 2, 2, radius)
            .unwrap();
        assert!(edge.feasible(steps));
        // Feasibility is per-axis: a wide-enough grid with a too-narrow
        // tile column still fails.
        let narrow = Decomposition2d::try_new(2 * (h + radius), 2 * h, 2, 2, radius).unwrap();
        assert!(!narrow.feasible(steps));
    }
}

#[cfg(test)]
mod tile_tests {
    use super::*;

    fn dc2(rows: usize, cols: usize, ty: usize, tx: usize, r: usize) -> Decomposition2d {
        Decomposition2d::try_new(rows, cols, ty, tx, r).unwrap()
    }

    fn cover_count(dc: &Decomposition2d, rects: &[Rect]) -> Vec<u8> {
        let mut cover = vec![0u8; dc.rows() * dc.cols()];
        for rect in rects {
            for r in rect.r0..rect.r1 {
                for c in rect.c0..rect.c1 {
                    cover[r * dc.cols() + c] += 1;
                }
            }
        }
        cover
    }

    #[test]
    fn owned_and_htod_and_dtoh_partition_grid() {
        for (rows, cols, ty, tx, r, steps) in
            [(120, 96, 3, 2, 1, 8), (90, 110, 2, 3, 2, 4), (64, 64, 1, 1, 1, 4)]
        {
            let dc = dc2(rows, cols, ty, tx, r);
            dc.check(steps);
            for (name, rects) in [
                ("owned", (0..dc.n_tiles()).map(|t| dc.owned(t)).collect::<Vec<_>>()),
                ("htod", (0..dc.n_tiles()).map(|t| dc.so2dr_htod(t, steps)).collect()),
                ("dtoh", (0..dc.n_tiles()).map(|t| dc.so2dr_dtoh(t)).collect()),
            ] {
                let cover = cover_count(&dc, &rects);
                assert!(
                    cover.iter().all(|&x| x == 1),
                    "{name} must partition the {rows}x{cols} grid ({ty}x{tx} tiles)"
                );
            }
        }
    }

    #[test]
    fn bands_tile_the_resident_rect_exactly() {
        // HtoD ∪ north ∪ west = resident, disjointly — the invariant
        // that lets a single row-major sweep satisfy every tile.
        let dc = dc2(120, 96, 3, 3, 2);
        let steps = 4;
        for t in 0..dc.n_tiles() {
            let res = dc.so2dr_resident(t, steps);
            let parts = [
                dc.so2dr_htod(t, steps),
                dc.so2dr_read_north(t, steps),
                dc.so2dr_read_west(t, steps),
            ];
            let mut area = 0usize;
            for p in &parts {
                assert!(res.contains_rect(p), "tile {t}: {p} outside resident {res}");
                area += p.area();
                for q in &parts {
                    if p != q {
                        assert!(!p.overlaps(q), "tile {t}: {p} overlaps {q}");
                    }
                }
            }
            assert_eq!(area, res.area(), "tile {t}: parts must cover resident exactly");
        }
    }

    #[test]
    fn write_bands_pair_with_neighbor_reads_and_fit_the_producer() {
        let dc = dc2(100, 100, 3, 3, 1);
        let steps = 5;
        for t in 0..dc.n_tiles() {
            let (i, j) = dc.tile_rc(t);
            let res = dc.so2dr_resident(t, steps);
            let south = dc.so2dr_write_south(t, steps);
            if i + 1 < dc.tiles_y() {
                assert_eq!(south, dc.so2dr_read_north(dc.index(i + 1, j), steps));
                assert!(!south.is_empty());
                assert!(res.contains_rect(&south), "tile {t} south {south} vs {res}");
            } else {
                assert!(south.is_empty());
            }
            let east = dc.so2dr_write_east(t, steps);
            if j + 1 < dc.tiles_x() {
                assert_eq!(east, dc.so2dr_read_west(dc.index(i, j + 1), steps));
                assert!(!east.is_empty());
                assert!(res.contains_rect(&east), "tile {t} east {east} vs {res}");
            } else {
                assert!(east.is_empty());
            }
        }
    }

    #[test]
    fn windows_shrink_to_owned_and_stay_one_radius_inside_resident() {
        let dc = dc2(120, 90, 2, 3, 2);
        let steps = 4;
        let (rows, cols, r) = (120i64, 90i64, 2i64);
        for t in 0..dc.n_tiles() {
            let o = dc.owned(t);
            let last = dc.so2dr_window(t, steps, steps);
            let interior_owned = Rect::clamped(
                (o.r0 as i64).max(r),
                (o.r1 as i64).min(rows - r),
                (o.c0 as i64).max(r),
                (o.c1 as i64).min(cols - r),
                120,
                90,
            );
            assert_eq!(last, interior_owned, "tile {t}");
            let res = dc.so2dr_resident(t, steps);
            for s in 1..=steps {
                let w = dc.so2dr_window(t, steps, s);
                // Every stencil read (window grown by r) stays resident.
                let reads = w.grow_clamped(2, 120, 90);
                assert!(res.contains_rect(&reads), "tile {t} step {s}: {reads} vs {res}");
                if s < steps {
                    assert!(w.area() >= dc.so2dr_window(t, steps, s + 1).area());
                }
            }
        }
    }

    #[test]
    fn windows_cover_interior_with_redundant_overlap() {
        let dc = dc2(80, 80, 2, 2, 1);
        let steps = 6;
        for s in 1..=steps {
            let rects: Vec<Rect> =
                (0..dc.n_tiles()).map(|t| dc.so2dr_window(t, steps, s)).collect();
            let cover = cover_count(&dc, &rects);
            for r in 1..79 {
                for c in 1..79 {
                    assert!(cover[r * 80 + c] >= 1, "step {s}: interior cell ({r},{c})");
                }
            }
        }
    }

    /// 1xN degenerate tiling: every span formula matches the 1-D
    /// decomposition exactly (full-width rects).
    #[test]
    fn one_by_n_matches_row_band_spans() {
        let (rows, cols, d, r, steps) = (200, 64, 4, 2, 6);
        let one = Decomposition::new(rows, cols, d, r);
        let two = dc2(rows, cols, d, 1, r);
        assert_eq!(two.feasible(steps), one.feasible(steps));
        for i in 0..d {
            let full = |s: RowSpan| Rect::from_spans(s, 0, cols);
            assert_eq!(two.owned(i), full(one.owned(i)), "owned {i}");
            assert_eq!(two.so2dr_htod(i, steps), full(one.so2dr_htod(i, steps)), "htod {i}");
            assert_eq!(two.so2dr_dtoh(i), full(one.so2dr_dtoh(i)), "dtoh {i}");
            let north = two.so2dr_read_north(i, steps);
            if i == 0 {
                assert!(north.is_empty());
            } else {
                assert_eq!(north, full(one.so2dr_rs_read(i, steps)), "north {i}");
            }
            assert!(two.so2dr_read_west(i, steps).is_empty());
            assert!(two.so2dr_write_east(i, steps).is_empty());
            let south = two.so2dr_write_south(i, steps);
            if i + 1 == d {
                assert!(south.is_empty());
            } else {
                assert_eq!(south, full(one.so2dr_rs_write(i, steps)), "south {i}");
            }
            for s in 1..=steps {
                let w1 = one.so2dr_window(i, steps, s);
                let w2 = two.so2dr_window(i, steps, s);
                assert_eq!(w2.rows(), w1, "window rows {i}@{s}");
                assert_eq!((w2.c0, w2.c1), (r, cols - r), "window cols {i}@{s}");
            }
        }
    }

    /// Nx1 is the transpose of 1xN: the column algebra mirrors the row
    /// algebra exactly.
    #[test]
    fn n_by_one_is_the_transpose_of_one_by_n() {
        let (rows, cols, d, r, steps) = (64, 200, 4, 2, 6);
        let wide = dc2(rows, cols, 1, d, r); // N tiles along columns
        let tall = dc2(cols, rows, d, 1, r); // the transposed grid
        let tr = |x: Rect| Rect::new(x.c0, x.c1, x.r0, x.r1);
        for t in 0..d {
            assert_eq!(wide.owned(t), tr(tall.owned(t)), "owned {t}");
            assert_eq!(wide.so2dr_htod(t, steps), tr(tall.so2dr_htod(t, steps)), "htod {t}");
            assert_eq!(
                wide.so2dr_read_west(t, steps),
                tr(tall.so2dr_read_north(t, steps)),
                "west {t}"
            );
            assert_eq!(
                wide.so2dr_write_east(t, steps),
                tr(tall.so2dr_write_south(t, steps)),
                "east {t}"
            );
            assert!(wide.so2dr_read_north(t, steps).is_empty());
            assert!(wide.so2dr_write_south(t, steps).is_empty());
        }
    }

    /// The tiling's reason to exist: at equal chunk count on a large
    /// square grid, the 2-D halo volume is strictly below the 1-D
    /// row-band volume (O(perimeter) vs O(cols) per chunk).
    #[test]
    fn square_tiling_halo_volume_beats_row_bands() {
        let (sz, r, steps) = (1024usize, 1, 8);
        for g in [2usize, 4] {
            let tiles = dc2(sz, sz, g, g, r);
            let halo_2d = tiles.halo_bytes_per_epoch(steps);
            // 1-D at the same chunk count: d-1 boundaries, 2h rows each.
            let d = g * g;
            let one = Decomposition::new(sz, sz, d, r);
            let halo_1d: u64 =
                (1..d).map(|i| one.so2dr_rs_read(i, steps).len() as u64 * sz as u64 * 4).sum();
            assert!(
                halo_2d < halo_1d,
                "{g}x{g} tiles: 2-D halo {halo_2d} !< 1-D halo {halo_1d}"
            );
        }
    }

    #[test]
    fn uniform_dims_cover_every_tile_epoch() {
        let dc = dc2(130, 110, 3, 2, 2);
        let s_max = 5;
        let (br, bc) = dc.uniform_buffer_dims(s_max);
        for t in 0..dc.n_tiles() {
            for steps in 1..=s_max {
                let res = dc.so2dr_resident(t, steps);
                let (base_r, base_c) = dc.tile_base(t, steps);
                assert!(res.r0 as i64 >= base_r && res.c0 as i64 >= base_c, "tile {t}");
                assert!(res.r1 as i64 <= base_r + br as i64, "tile {t} steps {steps}");
                assert!(res.c1 as i64 <= base_c + bc as i64, "tile {t} steps {steps}");
            }
        }
    }

    #[test]
    fn tile_indexing_roundtrip() {
        let dc = dc2(60, 60, 3, 4, 1);
        assert_eq!(dc.n_tiles(), 12);
        for t in 0..12 {
            let (i, j) = dc.tile_rc(t);
            assert_eq!(dc.index(i, j), t);
        }
    }

    #[test]
    fn settled_rects_partition_grid() {
        let dc = dc2(120, 96, 3, 2, 1);
        let rects: Vec<Rect> = (0..dc.n_tiles()).map(|t| dc.settled(t)).collect();
        let cover = cover_count(&dc, &rects);
        assert!(cover.iter().all(|&x| x == 1), "settled rects must partition the grid");
    }

    #[test]
    fn resident_fetch_bands_tile_the_resident_ring_exactly() {
        // settled ∪ west ∪ east ∪ north ∪ south = the epoch's resident
        // rect, disjointly — the invariant that makes the four-band
        // refresh (plus the settled arena) reconstruct exactly what the
        // staged HtoD + north/west reads would have delivered.
        let dc = dc2(120, 96, 3, 3, 2);
        let steps = 4;
        for t in 0..dc.n_tiles() {
            let res = dc.so2dr_resident(t, steps);
            let parts = [
                dc.settled(t),
                dc.resident_fetch_west(t, steps),
                dc.resident_fetch_east(t, steps),
                dc.resident_fetch_north(t, steps),
                dc.resident_fetch_south(t, steps),
            ];
            let mut area = 0usize;
            for p in &parts {
                assert!(res.contains_rect(p), "tile {t}: {p} outside resident {res}");
                area += p.area();
                for q in &parts {
                    if p != q && !p.is_empty() {
                        assert!(!p.overlaps(q), "tile {t}: {p} overlaps {q}");
                    }
                }
            }
            assert_eq!(area, res.area(), "tile {t}: parts must cover resident exactly");
        }
    }

    #[test]
    fn resident_fetch_bands_come_from_neighbor_coverage() {
        // Column bands lie inside the row neighbor's settled rect; row
        // bands lie inside the row neighbor's settled rect grown by its
        // own column fetches (the corner cascade). Edge tiles' missing
        // neighbors clamp the bands empty.
        let dc = dc2(120, 96, 3, 3, 2);
        let steps = 4;
        for t in 0..dc.n_tiles() {
            let (i, j) = dc.tile_rc(t);
            let west = dc.resident_fetch_west(t, steps);
            if j == 0 {
                assert!(west.is_empty(), "tile {t} has no west neighbor");
            } else {
                assert!(dc.settled(dc.index(i, j - 1)).contains_rect(&west), "tile {t}");
            }
            let east = dc.resident_fetch_east(t, steps);
            if j + 1 == dc.tiles_x() {
                assert!(east.is_empty());
            } else {
                assert!(dc.settled(dc.index(i, j + 1)).contains_rect(&east), "tile {t}");
            }
            let north = dc.resident_fetch_north(t, steps);
            if i == 0 {
                assert!(north.is_empty());
            } else {
                let p = dc.index(i - 1, j);
                // Publisher coverage after its column fetches: its
                // settled rows at the full skirted column width.
                let cov = Rect::of_spans(
                    dc.settled(p).rows(),
                    dc.resident_fetch_north(t, steps).cols(),
                );
                assert!(cov.contains_rect(&north), "tile {t}: {north} vs {cov}");
            }
            let south = dc.resident_fetch_south(t, steps);
            if i + 1 == dc.tiles_y() {
                assert!(south.is_empty());
            } else {
                let p = dc.index(i + 1, j);
                let cov = Rect::of_spans(
                    dc.settled(p).rows(),
                    dc.resident_fetch_south(t, steps).cols(),
                );
                assert!(cov.contains_rect(&south), "tile {t}: {south} vs {cov}");
            }
        }
    }

    #[test]
    fn resident_tile_keep_counts_scale_with_capacity() {
        let dc = dc2(120, 96, 2, 2, 1);
        let devs = DeviceAssignment::contiguous(4, 2);
        let s_max = 6;
        let none = devs.resident_tile_keep_counts(&dc, s_max, Some(1));
        let all = devs.resident_tile_keep_counts(&dc, s_max, None);
        let huge = devs.resident_tile_keep_counts(&dc, s_max, Some(u64::MAX));
        assert_eq!(none, vec![0, 0], "1-byte cap pins nothing");
        assert_eq!(all, vec![2, 2], "uncapped pins every tile");
        assert_eq!(huge, all);
    }

    #[test]
    fn resident_tile_demand_charges_every_arena() {
        // Same all-or-nothing boundary behavior as the 1-D model: a
        // capacity exactly at the demand pins everything, one byte less
        // pins nothing.
        let dc = dc2(120, 96, 2, 2, 1);
        let devs = DeviceAssignment::contiguous(4, 2);
        let s_max = 6;
        let nc = 2u64;
        let (br, bc) = dc.uniform_buffer_dims(s_max);
        let band = (dc.skirt(s_max) * br.max(bc) * 4) as u64;
        let demand = devs.resident_tile_memory_demand(&dc, 0, s_max);
        assert_eq!(demand, nc * dc.arena_bytes(s_max) + nc * 16 * band);
        assert_eq!(devs.resident_tile_keep_counts(&dc, s_max, Some(demand)), vec![2, 2]);
        assert_eq!(
            devs.resident_tile_keep_counts(&dc, s_max, Some(demand - 1)),
            vec![0, 0]
        );
    }

    /// Tile-side accept/reject table for heterogeneous caps — the 2-D
    /// twin of `hetero_caps_accept_reject_table`, same per-device
    /// all-or-nothing rule against each slot's own limit.
    #[test]
    fn tile_hetero_caps_accept_reject_table() {
        let dc = dc2(120, 96, 2, 2, 1);
        let devs = DeviceAssignment::contiguous(4, 2);
        let s_max = 6;
        let demand: Vec<u64> =
            (0..2).map(|dev| devs.resident_tile_memory_demand(&dc, dev, s_max)).collect();
        let cases: &[(Vec<Option<u64>>, Vec<usize>)] = &[
            (vec![None, None], vec![2, 2]),
            (vec![Some(demand[0]), Some(demand[1])], vec![2, 2]),
            (vec![Some(demand[0] - 1), Some(demand[1])], vec![0, 2]),
            (vec![Some(demand[0]), Some(demand[1] - 1)], vec![2, 0]),
            (vec![Some(1), Some(1)], vec![0, 0]),
        ];
        for (caps, want) in cases {
            let table = DeviceCaps::per_device(caps.clone());
            assert_eq!(
                devs.resident_tile_keep_counts_caps(&dc, s_max, &table),
                *want,
                "caps {caps:?}"
            );
        }
        for cap in [None, Some(1), Some(demand[0]), Some(u64::MAX)] {
            assert_eq!(
                devs.resident_tile_keep_counts(&dc, s_max, cap),
                devs.resident_tile_keep_counts_caps(&dc, s_max, &DeviceCaps::uniform(2, cap)),
                "cap {cap:?}"
            );
        }
    }
}
