//! The decomposition and raw span algebra.

use crate::core::geom::RowSpan;
use crate::stencil::StencilKind;
use crate::util::threads::split_range;

/// A 1-D (row-band) decomposition of a `rows x cols` grid into `d` chunks
/// for a stencil of radius `radius`.
#[derive(Debug, Clone)]
pub struct Decomposition {
    rows: usize,
    cols: usize,
    d: usize,
    radius: usize,
    /// `d + 1` chunk bounds: chunk `i` owns rows `[bounds[i], bounds[i+1])`.
    bounds: Vec<usize>,
}

impl Decomposition {
    /// Near-equal split. Panics if `d == 0` or `d > rows`.
    pub fn new(rows: usize, cols: usize, d: usize, radius: usize) -> Self {
        assert!(d > 0 && d <= rows, "invalid chunk count d={d} for {rows} rows");
        assert!(radius > 0, "radius must be positive");
        let parts = split_range(0, rows, d);
        assert_eq!(parts.len(), d, "rows too few for d={d}");
        let mut bounds: Vec<usize> = parts.iter().map(|&(a, _)| a).collect();
        bounds.push(rows);
        Self { rows, cols, d, radius, bounds }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn n_chunks(&self) -> usize {
        self.d
    }

    pub fn radius(&self) -> usize {
        self.radius
    }

    /// Rows owned by chunk `i`.
    pub fn owned(&self, i: usize) -> RowSpan {
        RowSpan::new(self.bounds[i], self.bounds[i + 1])
    }

    /// Smallest chunk height.
    pub fn min_chunk_rows(&self) -> usize {
        (0..self.d).map(|i| self.owned(i).len()).min().unwrap()
    }

    /// Skirt height `h = steps * radius` for an epoch of `steps`.
    pub fn skirt(&self, steps: usize) -> usize {
        steps * self.radius
    }

    /// Check the feasibility precondition for an epoch of `steps` TB steps:
    /// the skirt plus one radius must fit inside every chunk, so compute
    /// windows stay affine in the step index (paper constraint
    /// `W_halo * S_TB <= D_chk`, tightened by `r` for the Dirichlet ring).
    pub fn feasible(&self, steps: usize) -> bool {
        self.skirt(steps) + self.radius <= self.min_chunk_rows()
    }

    /// Assert feasibility with a readable message.
    pub fn check(&self, steps: usize) {
        assert!(
            self.feasible(steps),
            "infeasible: skirt {} + r {} > min chunk {} (d={}, steps={})",
            self.skirt(steps),
            self.radius,
            self.min_chunk_rows(),
            self.d,
            steps
        );
    }

    // ---------------------------------------------------------------
    // SO2DR (trapezoid) spans, parameterized by the epoch's step count.
    // ---------------------------------------------------------------

    /// Rows resident on the device for chunk `i` during an epoch of
    /// `steps`: owned rows plus the `h`-row skirt on each side (clamped).
    pub fn so2dr_resident(&self, i: usize, steps: usize) -> RowSpan {
        let h = self.skirt(steps) as i64;
        let o = self.owned(i);
        RowSpan::clamped(o.lo as i64 - h, o.hi as i64 + h, self.rows)
    }

    /// Rows transferred host→device for chunk `i`: the resident span minus
    /// what the region-sharing buffer provides (raw rows saved by chunk
    /// `i-1`). Chunk 0 transfers its whole resident span. Per epoch the
    /// HtoD spans partition `[0, rows)` — zero redundant transfer.
    pub fn so2dr_htod(&self, i: usize, steps: usize) -> RowSpan {
        let h = self.skirt(steps) as i64;
        let o = self.owned(i);
        if i == 0 {
            RowSpan::clamped(0, o.hi as i64 + h, self.rows)
        } else {
            RowSpan::clamped(o.lo as i64 + h, o.hi as i64 + h, self.rows)
        }
    }

    /// Raw (epoch-start) rows chunk `i` reads from the region-sharing
    /// buffer: its lower skirt plus its own first `h` rows, all saved by
    /// chunk `i-1`. Empty for chunk 0.
    pub fn so2dr_rs_read(&self, i: usize, steps: usize) -> RowSpan {
        if i == 0 {
            return RowSpan::empty();
        }
        let h = self.skirt(steps) as i64;
        let o = self.owned(i);
        RowSpan::clamped(o.lo as i64 - h, o.lo as i64 + h, self.rows)
    }

    /// Raw rows chunk `i` writes to the region-sharing buffer for chunk
    /// `i+1` (must happen before its kernels overwrite them). Empty for the
    /// last chunk.
    pub fn so2dr_rs_write(&self, i: usize, steps: usize) -> RowSpan {
        if i + 1 == self.d {
            return RowSpan::empty();
        }
        let h = self.skirt(steps) as i64;
        let b = self.bounds[i + 1] as i64;
        RowSpan::clamped(b - h, b + h, self.rows)
    }

    /// Rows transferred device→host after the epoch: exactly the owned rows.
    pub fn so2dr_dtoh(&self, i: usize) -> RowSpan {
        self.owned(i)
    }

    /// Compute window (rows) for chunk `i` at TB step `s` (1-based,
    /// `1 <= s <= steps`): the trapezoid `[a_i - (steps-s)*r,
    /// a_{i+1} + (steps-s)*r)`, clamped to the Dirichlet interior
    /// `[r, rows-r)`.
    pub fn so2dr_window(&self, i: usize, steps: usize, s: usize) -> RowSpan {
        assert!((1..=steps).contains(&s));
        let grow = ((steps - s) * self.radius) as i64;
        let o = self.owned(i);
        let lo = o.lo as i64 - grow;
        let hi = o.hi as i64 + grow;
        let r = self.radius as i64;
        RowSpan::clamped(lo.max(r), hi.min(self.rows as i64 - r), self.rows)
    }

    /// Redundant rows computed at step `s` across all chunk boundaries
    /// (each boundary overlap is `2*(steps-s)*r` rows, clamped by the
    /// interior). Used to cross-check the closed-form redundancy model.
    pub fn so2dr_redundant_rows(&self, steps: usize, s: usize) -> usize {
        let mut total = 0usize;
        for i in 0..self.d.saturating_sub(1) {
            let a = self.so2dr_window(i, steps, s);
            let b = self.so2dr_window(i + 1, steps, s);
            total += a.intersect(&b).len();
        }
        total
    }

    // ---------------------------------------------------------------
    // ResReu (skewed parallelogram) spans.
    // ---------------------------------------------------------------

    /// Rows resident for chunk `i` under ResReu: owned rows plus the lower
    /// working space of `h + r` rows (windows shift downward by `h` over
    /// the epoch and the final window still reads `r` rows below itself).
    /// The last chunk additionally keeps its bottom rows (its window's
    /// upper edge does not shift).
    pub fn resreu_resident(&self, i: usize, steps: usize) -> RowSpan {
        let h = (self.skirt(steps) + self.radius) as i64;
        let o = self.owned(i);
        RowSpan::clamped(o.lo as i64 - h, o.hi as i64, self.rows)
    }

    /// HtoD span under ResReu: exactly the owned rows (intermediate halo
    /// data arrives through the region-sharing buffer).
    pub fn resreu_htod(&self, i: usize) -> RowSpan {
        self.owned(i)
    }

    /// Compute window at step `s` (1-based): `[a_i - s*r, a_{i+1} - s*r)`
    /// shifted by the skew; chunk 0's lower edge clamps at the interior
    /// boundary and the last chunk's upper edge stays at `rows - r`.
    pub fn resreu_window(&self, i: usize, steps: usize, s: usize) -> RowSpan {
        assert!((1..=steps).contains(&s));
        let shift = (s * self.radius) as i64;
        let o = self.owned(i);
        let r = self.radius as i64;
        let lo = if i == 0 { r } else { o.lo as i64 - shift };
        let hi = if i + 1 == self.d { self.rows as i64 - r } else { o.hi as i64 - shift };
        RowSpan::clamped(lo.max(r), hi.min(self.rows as i64 - r), self.rows)
    }

    /// Rows (time `s-1` data) chunk `i` reads from the RS buffer before
    /// step `s`: `2r` rows below its shifted window, produced by chunk
    /// `i-1`. Empty for chunk 0.
    pub fn resreu_rs_read(&self, i: usize, s: usize) -> RowSpan {
        if i == 0 {
            return RowSpan::empty();
        }
        let a = self.bounds[i] as i64;
        let r = self.radius as i64;
        let s = s as i64;
        RowSpan::clamped(a - s * r - r, a - (s - 1) * r, self.rows)
    }

    /// Rows (time `s-1` data) chunk `i` writes to the RS buffer before
    /// step `s` for chunk `i+1`; by construction
    /// `resreu_rs_write(i, s) == resreu_rs_read(i+1, s)`. Empty for the
    /// last chunk.
    pub fn resreu_rs_write(&self, i: usize, s: usize) -> RowSpan {
        if i + 1 == self.d {
            return RowSpan::empty();
        }
        let b = self.bounds[i + 1] as i64;
        let r = self.radius as i64;
        let s = s as i64;
        RowSpan::clamped(b - s * r - r, b - (s - 1) * r, self.rows)
    }

    /// DtoH span after an epoch of `steps`: the skew-shifted owned rows
    /// (chunk 0 keeps its top, the last chunk keeps its bottom); the spans
    /// partition `[0, rows)`.
    pub fn resreu_dtoh(&self, i: usize, steps: usize) -> RowSpan {
        let h = self.skirt(steps) as i64;
        let o = self.owned(i);
        let lo = if i == 0 { 0 } else { o.lo as i64 - h };
        let hi = if i + 1 == self.d { self.rows as i64 } else { o.hi as i64 - h };
        RowSpan::clamped(lo, hi, self.rows)
    }

    // ---------------------------------------------------------------
    // Resident-model spans (cross-epoch residency; see chunking::plan).
    //
    // After an epoch, each chunk's arena holds a *settled* span: rows
    // valid at the epoch-end time step. The settled spans partition
    // `[0, rows)`, so an evicted chunk can spill exactly its settled
    // span and re-fetch it from the host later, while the epoch-start
    // skirt/halo of the next epoch is refreshed from the neighbors'
    // settled spans (fetch spans below) instead of a host round trip.
    // ---------------------------------------------------------------

    /// Rows of chunk `i` that are valid at the current time step in its
    /// arena after an epoch of `steps`: the chunk's writeback span. For
    /// SO2DR this is the owned span (the last trapezoid step computes
    /// exactly the owned rows); for ResReu it is the skew-shifted
    /// [`Self::resreu_dtoh`] span. Settled spans partition `[0, rows)`.
    pub fn settled(&self, scheme: crate::chunking::Scheme, i: usize, steps: usize) -> RowSpan {
        match scheme {
            crate::chunking::Scheme::So2dr => self.owned(i),
            crate::chunking::Scheme::ResReu => self.resreu_dtoh(i, steps),
            crate::chunking::Scheme::InCore => RowSpan::new(0, self.rows),
        }
    }

    /// Lower skirt chunk `i` must fetch at the start of a resident SO2DR
    /// epoch of `steps`: `[lo - h', lo)`, produced (settled) by chunk
    /// `i-1`. Empty for chunk 0 (clamped at the grid edge).
    pub fn so2dr_fetch_low(&self, i: usize, steps: usize) -> RowSpan {
        let h = self.skirt(steps) as i64;
        let o = self.owned(i);
        RowSpan::clamped(o.lo as i64 - h, o.lo as i64, self.rows)
    }

    /// Upper skirt chunk `i` must fetch at the start of a resident SO2DR
    /// epoch of `steps`: `[hi, hi + h')`, settled by chunk `i+1`. Empty
    /// for the last chunk.
    pub fn so2dr_fetch_high(&self, i: usize, steps: usize) -> RowSpan {
        let h = self.skirt(steps) as i64;
        let o = self.owned(i);
        RowSpan::clamped(o.hi as i64, o.hi as i64 + h, self.rows)
    }

    /// Rows chunk `i` must fetch at the start of a resident ResReu epoch:
    /// the previous epoch's windows shifted down by `h_prev`, so the top
    /// `[hi - h_prev, hi)` of the owned span is settled in chunk `i+1`'s
    /// arena. Empty for the last chunk (its window's upper edge does not
    /// shift, so it settles its whole tail itself).
    pub fn resreu_fetch(&self, i: usize, prev_steps: usize) -> RowSpan {
        if i + 1 == self.d {
            return RowSpan::empty();
        }
        let h = self.skirt(prev_steps) as i64;
        let o = self.owned(i);
        RowSpan::clamped(o.hi as i64 - h, o.hi as i64, self.rows)
    }

    /// Uniform chunk-arena height for a whole run with at most `s_max` TB
    /// steps per epoch: tall enough for the largest epoch of any chunk, so
    /// fixed-shape (AOT-compiled) kernels serve every chunk and epoch and
    /// resident arenas keep a stable base across epochs.
    pub fn uniform_buffer_rows(&self, scheme: crate::chunking::Scheme, s_max: usize) -> usize {
        let max_own = (0..self.d).map(|i| self.owned(i).len()).max().unwrap();
        match scheme {
            crate::chunking::Scheme::So2dr => max_own + 2 * s_max * self.radius,
            crate::chunking::Scheme::ResReu => max_own + s_max * self.radius + self.radius,
            crate::chunking::Scheme::InCore => self.rows,
        }
    }

    /// Signed global row of chunk `i`'s arena base under the resident
    /// execution model: fixed across epochs (sized for `s_max`), so data
    /// keeps its arena offset from one epoch to the next.
    pub fn resident_base(
        &self,
        scheme: crate::chunking::Scheme,
        s_max: usize,
        i: usize,
    ) -> i64 {
        let r = self.radius as i64;
        let h = (s_max * self.radius) as i64;
        match scheme {
            crate::chunking::Scheme::So2dr => self.owned(i).lo as i64 - h,
            crate::chunking::Scheme::ResReu => self.owned(i).lo as i64 - h - r,
            crate::chunking::Scheme::InCore => 0,
        }
    }

    /// Bytes of one chunk arena (input + output double buffer) at the
    /// uniform height `buf_rows`.
    pub fn arena_bytes(&self, buf_rows: usize) -> u64 {
        2 * (buf_rows * self.cols * 4) as u64
    }

    /// Uncompressed payload bytes of a transfer covering `span` rows.
    /// The codec policy's size thresholds and the planner's byte
    /// accounting go through here so they cannot drift; the executor's
    /// counters and the flattener keep a hoisted `cols * 4` of the same
    /// formula on their hot paths.
    pub fn span_bytes(&self, span: RowSpan) -> u64 {
        (span.len() * self.cols * 4) as u64
    }

    // ---------------------------------------------------------------
    // Paper model quantities (Section III / IV-C).
    // ---------------------------------------------------------------

    /// `D_chk` in bytes for one chunk (f32 elements).
    pub fn chunk_bytes(&self, i: usize) -> u64 {
        (self.owned(i).len() * self.cols * 4) as u64
    }

    /// `W_halo` in bytes: one radius-deep halo region pair
    /// (`2r * cols` elements), the paper's per-TB-step working space.
    pub fn halo_bytes_per_step(&self) -> u64 {
        (2 * self.radius * self.cols * 4) as u64
    }

    /// Device-resident bytes for chunk `i` during an epoch of `steps`
    /// (`D_chk + W_halo*S_TB`), for the memory-capacity constraint.
    pub fn resident_bytes(&self, i: usize, steps: usize, kind: StencilKind) -> u64 {
        let _ = kind; // radius already captured in self.radius
        self.chunk_bytes(i) + self.halo_bytes_per_step() * steps as u64
    }
}

/// Assignment of chunks to devices for a sharded (multi-GPU) run.
///
/// Chunks are mapped to devices in contiguous near-equal blocks, so the
/// only inter-device halo traffic is at the `n_devices - 1` block
/// boundaries — every interior region share stays a cheap on-device copy,
/// and a boundary share becomes a peer-to-peer (`D2D`) link transfer.
/// Devices are modeled homogeneous (same capacity and bandwidths).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DeviceAssignment {
    n_devices: usize,
    /// `of_chunk[i]` = device owning chunk `i` (non-decreasing).
    of_chunk: Vec<usize>,
}

impl DeviceAssignment {
    /// Contiguous near-equal split of `n_chunks` chunks over `n_devices`
    /// devices. Panics if `n_devices == 0` or `n_devices > n_chunks`.
    pub fn contiguous(n_chunks: usize, n_devices: usize) -> Self {
        assert!(
            n_devices > 0 && n_devices <= n_chunks,
            "invalid device count {n_devices} for {n_chunks} chunks"
        );
        let parts = split_range(0, n_chunks, n_devices);
        assert_eq!(parts.len(), n_devices);
        let mut of_chunk = vec![0usize; n_chunks];
        for (dev, &(a, b)) in parts.iter().enumerate() {
            for item in of_chunk.iter_mut().take(b).skip(a) {
                *item = dev;
            }
        }
        Self { n_devices, of_chunk }
    }

    /// Everything on one device (the seed's original behavior).
    pub fn single(n_chunks: usize) -> Self {
        Self::contiguous(n_chunks, 1)
    }

    pub fn n_devices(&self) -> usize {
        self.n_devices
    }

    pub fn n_chunks(&self) -> usize {
        self.of_chunk.len()
    }

    /// Device owning chunk `i`.
    pub fn device_of(&self, chunk: usize) -> usize {
        self.of_chunk[chunk]
    }

    /// Chunk index range owned by device `dev`.
    pub fn chunks_on(&self, dev: usize) -> std::ops::Range<usize> {
        let lo = self.of_chunk.iter().position(|&d| d == dev).unwrap_or(0);
        let hi = self.of_chunk.iter().rposition(|&d| d == dev).map(|p| p + 1).unwrap_or(0);
        lo..hi
    }

    /// True when chunks `i` and `i + 1` live on different devices, i.e.
    /// their region share must cross the inter-device link.
    pub fn crosses_boundary(&self, i: usize) -> bool {
        i + 1 < self.of_chunk.len() && self.of_chunk[i] != self.of_chunk[i + 1]
    }

    /// Per-device capacity accounting: device-memory bytes demanded on
    /// each device when up to `n_strm` chunk pipelines are in flight per
    /// device, each double buffered, during an epoch of `steps` —
    /// the multi-device analog of the §IV-C memory constraint
    /// `(D_chk + W_halo*S_TB) * N_strm * N_buf <= C_dmem`, now checked
    /// per shard instead of globally.
    pub fn device_memory_demand(
        &self,
        dc: &Decomposition,
        steps: usize,
        n_strm: usize,
        kind: StencilKind,
    ) -> Vec<u64> {
        (0..self.n_devices)
            .map(|dev| {
                let chunks = self.chunks_on(dev);
                let live = n_strm.max(1).min(chunks.len().max(1)) as u64;
                let worst = chunks
                    .map(|i| dc.resident_bytes(i, steps, kind))
                    .max()
                    .unwrap_or(0);
                live * 2 * worst
            })
            .collect()
    }

    /// Device-memory demand (bytes) of a resident-model run on device
    /// `dev`: one arena per chunk assigned to the device, plus a
    /// region-sharing slack of `12 * h_max` rows per chunk.
    ///
    /// The arena term charges *every* chunk — not just pinned ones —
    /// because resident epochs execute in two phases (all arrivals and
    /// publishes before any fetch/kernel/eviction), so at the epoch
    /// boundary every chunk's arena on the device is live at once:
    /// spilled chunks re-allocate in phase A and only release at their
    /// phase-B `Evict`. Spilling therefore saves host traffic modeling,
    /// not peak arena footprint, in the current execution model;
    /// staggering spilled arrivals to reclaim that peak is a ROADMAP
    /// follow-on. The slack dominates the worst case of either scheme:
    /// a chunk-epoch's sharing allocations (its region writes,
    /// publishes, and incoming link copies) total at most `4 * h` rows
    /// for SO2DR and `6 * h` for ResReu, live until their consumer
    /// retires, and at most two adjacent epochs' regions can overlap on
    /// a device. The DES's observed peak never exceeds this bound,
    /// which is what lets the planner promise `capacity_exceeded` won't
    /// fire on accepted plans.
    pub fn resident_memory_demand(
        &self,
        dc: &Decomposition,
        dev: usize,
        buf_rows: usize,
        h_max: usize,
    ) -> u64 {
        let nc = self.chunks_on(dev).len() as u64;
        let rs_slack = nc * 12 * (h_max * dc.cols() * 4) as u64;
        nc * dc.arena_bytes(buf_rows) + rs_slack
    }

    /// Per-device pinned-chunk counts under `cap` bytes and
    /// [`Self::resident_memory_demand`]. Because the epoch-boundary
    /// footprint is the same whether chunks pin or spill (see above),
    /// the decision is all-or-nothing per device: pin everything when
    /// the device's demand fits (pinning only removes host traffic),
    /// else pin nothing and spill every epoch. `None` caps nothing
    /// (keep all).
    pub fn resident_keep_counts(
        &self,
        dc: &Decomposition,
        buf_rows: usize,
        h_max: usize,
        cap: Option<u64>,
    ) -> Vec<usize> {
        (0..self.n_devices)
            .map(|dev| {
                let nc = self.chunks_on(dev).len();
                match cap {
                    None => nc,
                    Some(cap) => {
                        if self.resident_memory_demand(dc, dev, buf_rows, h_max) <= cap {
                            nc
                        } else {
                            0
                        }
                    }
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dec(rows: usize, d: usize, r: usize) -> Decomposition {
        Decomposition::new(rows, 64, d, r)
    }

    #[test]
    fn bounds_partition_rows() {
        let dc = dec(103, 4, 1);
        let mut cur = 0;
        for i in 0..4 {
            let o = dc.owned(i);
            assert_eq!(o.lo, cur);
            cur = o.hi;
        }
        assert_eq!(cur, 103);
    }

    #[test]
    fn so2dr_htod_partitions_grid() {
        for (rows, d, r, steps) in [(120, 4, 1, 8), (200, 5, 2, 4), (96, 3, 4, 2)] {
            let dc = dec(rows, d, r);
            dc.check(steps);
            let mut cur = 0;
            for i in 0..d {
                let t = dc.so2dr_htod(i, steps);
                assert_eq!(t.lo, cur, "chunk {i}");
                cur = t.hi;
            }
            assert_eq!(cur, rows);
        }
    }

    #[test]
    fn so2dr_rs_pairs_match() {
        let dc = dec(160, 4, 2);
        let steps = 6;
        for i in 1..4 {
            assert_eq!(dc.so2dr_rs_read(i, steps), dc.so2dr_rs_write(i - 1, steps));
        }
        assert!(dc.so2dr_rs_read(0, steps).is_empty());
        assert!(dc.so2dr_rs_write(3, steps).is_empty());
    }

    #[test]
    fn so2dr_window_shrinks_to_owned() {
        let dc = dec(160, 4, 2);
        let steps = 6;
        // Final step's window == owned rows (clamped to interior).
        for i in 0..4 {
            let w = dc.so2dr_window(i, steps, steps);
            let o = dc.owned(i);
            let expect = RowSpan::clamped(
                o.lo.max(2) as i64,
                o.hi.min(158) as i64,
                160,
            );
            assert_eq!(w, expect, "chunk {i}");
        }
        // Windows grow toward earlier steps.
        for s in 1..steps {
            assert!(dc.so2dr_window(1, steps, s).len() > dc.so2dr_window(1, steps, s + 1).len());
        }
    }

    #[test]
    fn so2dr_window_within_resident_minus_r() {
        let dc = dec(160, 4, 2);
        let steps = 6;
        for i in 0..4 {
            let res = dc.so2dr_resident(i, steps);
            for s in 1..=steps {
                let w = dc.so2dr_window(i, steps, s);
                assert!(w.lo >= res.lo + 2 || (res.lo == 0 && w.lo >= 2));
                assert!(w.hi + 2 <= res.hi || (res.hi == 160 && w.hi <= 158));
            }
        }
    }

    #[test]
    fn so2dr_redundancy_closed_form() {
        let dc = dec(400, 4, 1);
        let steps = 10;
        for s in 1..=steps {
            // Interior boundaries, no clamping at this size:
            // overlap per boundary = 2*(steps-s)*r.
            assert_eq!(dc.so2dr_redundant_rows(steps, s), 3 * 2 * (steps - s));
        }
    }

    #[test]
    fn resreu_windows_tile_interior() {
        let dc = dec(200, 4, 2);
        let steps = 5;
        dc.check(steps);
        for s in 1..=steps {
            let mut cur = 2; // interior starts at r
            for i in 0..4 {
                let w = dc.resreu_window(i, steps, s);
                assert_eq!(w.lo, cur, "step {s} chunk {i}");
                cur = w.hi;
            }
            assert_eq!(cur, 198); // rows - r
        }
    }

    #[test]
    fn resreu_rs_pairs_match() {
        let dc = dec(200, 4, 2);
        for s in 1..=5 {
            for i in 1..4 {
                assert_eq!(dc.resreu_rs_read(i, s), dc.resreu_rs_write(i - 1, s));
                assert_eq!(dc.resreu_rs_read(i, s).len(), 2 * 2); // 2r rows
            }
        }
    }

    #[test]
    fn resreu_dtoh_partitions_grid() {
        let dc = dec(200, 4, 2);
        let steps = 5;
        let mut cur = 0;
        for i in 0..4 {
            let t = dc.resreu_dtoh(i, steps);
            assert_eq!(t.lo, cur);
            cur = t.hi;
        }
        assert_eq!(cur, 200);
    }

    #[test]
    fn resreu_window_needs_only_resident_rows() {
        let dc = dec(200, 4, 2);
        let steps = 5;
        for i in 0..4 {
            let res = dc.resreu_resident(i, steps);
            for s in 1..=steps {
                let w = dc.resreu_window(i, steps, s);
                // Reads beyond the lower edge are satisfied by RS reads
                // of 2r rows just below w.lo, which land inside resident.
                let rs = dc.resreu_rs_read(i, s);
                if i > 0 {
                    assert!(res.contains_span(&rs), "chunk {i} step {s}: rs {rs} vs res {res}");
                }
                assert!(w.hi + 2 <= res.hi + 2 + 1, "upper edge inside resident + r");
            }
        }
    }

    #[test]
    fn feasibility_boundary() {
        let dc = dec(100, 4, 1); // chunks of 25 rows
        assert!(dc.feasible(24));
        assert!(!dc.feasible(25));
    }

    #[test]
    fn paper_model_bytes() {
        let dc = Decomposition::new(1000, 500, 4, 2);
        assert_eq!(dc.chunk_bytes(0), 250 * 500 * 4);
        assert_eq!(dc.halo_bytes_per_step(), 2 * 2 * 500 * 4);
        assert_eq!(
            dc.resident_bytes(0, 10, StencilKind::Box { radius: 2 }),
            250 * 500 * 4 + 10 * 2 * 2 * 500 * 4
        );
    }

    #[test]
    fn device_assignment_contiguous_blocks() {
        let devs = DeviceAssignment::contiguous(8, 4);
        assert_eq!(devs.n_devices(), 4);
        assert_eq!(devs.n_chunks(), 8);
        for i in 0..8 {
            assert_eq!(devs.device_of(i), i / 2);
        }
        assert_eq!(devs.chunks_on(0), 0..2);
        assert_eq!(devs.chunks_on(3), 6..8);
        // Boundaries exactly between blocks.
        let boundaries: Vec<usize> = (0..7).filter(|&i| devs.crosses_boundary(i)).collect();
        assert_eq!(boundaries, vec![1, 3, 5]);
    }

    #[test]
    fn device_assignment_uneven_split() {
        let devs = DeviceAssignment::contiguous(5, 2);
        // Non-decreasing, both devices non-empty, sizes differ by <= 1.
        let on0 = devs.chunks_on(0).len();
        let on1 = devs.chunks_on(1).len();
        assert_eq!(on0 + on1, 5);
        assert!(on0.abs_diff(on1) <= 1);
        for i in 1..5 {
            assert!(devs.device_of(i) >= devs.device_of(i - 1));
        }
    }

    #[test]
    fn single_device_has_no_boundaries() {
        let devs = DeviceAssignment::single(6);
        assert_eq!(devs.n_devices(), 1);
        assert!((0..6).all(|i| !devs.crosses_boundary(i)));
        assert_eq!(devs.chunks_on(0), 0..6);
    }

    #[test]
    fn device_memory_demand_shrinks_with_more_devices() {
        let dc = Decomposition::new(960, 256, 8, 1);
        let kind = StencilKind::Box { radius: 1 };
        let one = DeviceAssignment::single(8).device_memory_demand(&dc, 8, 3, kind);
        let four = DeviceAssignment::contiguous(8, 4).device_memory_demand(&dc, 8, 3, kind);
        assert_eq!(one.len(), 1);
        assert_eq!(four.len(), 4);
        // Fewer in-flight pipelines per shard -> lower per-device demand.
        assert!(four.iter().max().unwrap() <= &one[0]);
    }

    #[test]
    #[should_panic(expected = "invalid device count")]
    fn more_devices_than_chunks_rejected() {
        DeviceAssignment::contiguous(2, 3);
    }

    #[test]
    fn settled_spans_partition_grid() {
        use crate::chunking::Scheme;
        let dc = dec(200, 4, 2);
        for (scheme, steps) in [(Scheme::So2dr, 6), (Scheme::ResReu, 5)] {
            let mut cur = 0;
            for i in 0..4 {
                let s = dc.settled(scheme, i, steps);
                assert_eq!(s.lo, cur, "{scheme:?} chunk {i}");
                cur = s.hi;
            }
            assert_eq!(cur, 200, "{scheme:?}");
        }
    }

    #[test]
    fn so2dr_fetch_spans_come_from_neighbor_settled() {
        use crate::chunking::Scheme;
        let dc = dec(200, 4, 2);
        let steps = 6;
        for i in 0..4 {
            let low = dc.so2dr_fetch_low(i, steps);
            let high = dc.so2dr_fetch_high(i, steps);
            if i == 0 {
                assert!(low.is_empty(), "chunk 0 has no lower neighbor");
            } else {
                assert_eq!(low.len(), dc.skirt(steps));
                assert!(dc.settled(Scheme::So2dr, i - 1, steps).contains_span(&low));
            }
            if i + 1 == 4 {
                assert!(high.is_empty(), "last chunk has no upper neighbor");
            } else {
                assert_eq!(high.len(), dc.skirt(steps));
                assert!(dc.settled(Scheme::So2dr, i + 1, steps).contains_span(&high));
            }
            // Settled + fetches cover the epoch's resident requirement.
            let covered = low.hull(&dc.owned(i)).hull(&high);
            assert_eq!(covered, dc.so2dr_resident(i, steps), "chunk {i}");
        }
    }

    #[test]
    fn resreu_fetch_spans_come_from_upper_neighbor_settled() {
        use crate::chunking::Scheme;
        let dc = dec(200, 4, 2);
        let prev_steps = 5;
        for i in 0..4 {
            let f = dc.resreu_fetch(i, prev_steps);
            if i + 1 == 4 {
                assert!(f.is_empty());
                continue;
            }
            assert_eq!(f.len(), dc.skirt(prev_steps));
            assert!(dc.settled(Scheme::ResReu, i + 1, prev_steps).contains_span(&f));
            // Own settled + fetch covers the owned epoch-start span.
            let s = dc.settled(Scheme::ResReu, i, prev_steps);
            assert!(s.hull(&f).contains_span(&dc.owned(i)), "chunk {i}");
        }
    }

    #[test]
    fn resident_keep_counts_scale_with_capacity() {
        let dc = Decomposition::new(960, 256, 8, 1);
        let devs = DeviceAssignment::contiguous(8, 2);
        let buf_rows = dc.uniform_buffer_rows(crate::chunking::Scheme::So2dr, 8);
        let none = devs.resident_keep_counts(&dc, buf_rows, 8, Some(1));
        let all = devs.resident_keep_counts(&dc, buf_rows, 8, None);
        let huge = devs.resident_keep_counts(&dc, buf_rows, 8, Some(u64::MAX));
        assert_eq!(none, vec![0, 0], "1-byte cap pins nothing");
        assert_eq!(all, vec![4, 4], "uncapped pins every chunk");
        assert_eq!(huge, all);
    }

    #[test]
    fn resident_demand_charges_every_chunk_arena() {
        // The two-phase epoch boundary holds every chunk's arena at once
        // (spilled chunks re-arrive in phase A and only evict in phase
        // B), so demand must charge nc arenas — spilling cannot lower
        // the modeled peak, only pinning-vs-not changes host traffic.
        let dc = Decomposition::new(960, 256, 8, 1);
        let devs = DeviceAssignment::contiguous(8, 2);
        let buf_rows = dc.uniform_buffer_rows(crate::chunking::Scheme::So2dr, 8);
        let nc = 4u64; // chunks per device
        let demand = devs.resident_memory_demand(&dc, 0, buf_rows, 8);
        let slack = nc * 12 * (8 * 256 * 4) as u64;
        assert_eq!(demand, nc * dc.arena_bytes(buf_rows) + slack);
        // A capacity exactly at the demand pins everything; one byte
        // less pins nothing (all-or-nothing per device).
        assert_eq!(devs.resident_keep_counts(&dc, buf_rows, 8, Some(demand)), vec![4, 4]);
        assert_eq!(
            devs.resident_keep_counts(&dc, buf_rows, 8, Some(demand - 1)),
            vec![0, 0]
        );
    }

    #[test]
    fn uniform_buffer_rows_cover_every_epoch_span() {
        use crate::chunking::Scheme;
        let dc = dec(200, 4, 2);
        let s_max = 6;
        for scheme in [Scheme::So2dr, Scheme::ResReu] {
            let rows = dc.uniform_buffer_rows(scheme, s_max);
            for i in 0..4 {
                let base = dc.resident_base(scheme, s_max, i);
                for steps in 1..=s_max {
                    let span = match scheme {
                        Scheme::So2dr => dc.so2dr_resident(i, steps),
                        _ => dc.resreu_resident(i, steps),
                    };
                    assert!(span.lo as i64 >= base, "{scheme:?} chunk {i} steps {steps}");
                    assert!(
                        span.hi as i64 <= base + rows as i64,
                        "{scheme:?} chunk {i} steps {steps}"
                    );
                }
            }
        }
    }
}
