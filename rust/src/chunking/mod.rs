//! Chunk decomposition and region-sharing geometry.
//!
//! The grid (`rows x cols`) is split along rows into `d` chunks — the
//! paper's 1-D decomposition (`D_chk = sz(sz+2r)^{dim-1}/d`) — or, with
//! `--decomp tiles`, into a `chunks_y x chunks_x` grid of rectangular
//! tiles ([`Decomposition2d`]) whose per-tile halo volume scales with
//! the tile *perimeter* instead of the full grid width. This module is
//! pure integer geometry: all spans/rects are in *global grid
//! coordinates*; the coordinator translates to chunk-buffer-local
//! coordinates.
//!
//! Two sharing schemes are supported (see DESIGN.md §4):
//!
//! * **SO2DR (trapezoid + redundant computation).** An epoch of `S` steps
//!   gives each chunk a *skirt* of `h = S*r` rows on each side. Epoch-start
//!   (raw) halo rows are shared via the region-sharing buffer; rows near a
//!   chunk boundary are computed by both neighbors (redundant compute), in
//!   exchange for `S` uninterrupted steps per chunk.
//! * **ResReu (skewed parallelogram, Jin et al. 2013).** Compute windows
//!   shift down by `r` rows per step; before each step a chunk reads `2r`
//!   rows of the *previous step's intermediate results* produced by its
//!   lower neighbor and writes its own trailing `2r` rows for the upper
//!   neighbor. No redundant transfer or compute — but kernels are
//!   structurally single-step.
//!
//! Invariants (property-tested in `rust/tests/prop_chunking.rs`):
//! - per epoch, HtoD spans partition `[0, rows)` exactly (both schemes);
//! - per epoch, DtoH spans partition `[0, rows)` exactly;
//! - every compute window stays inside the chunk's resident span shrunk by
//!   `r` (all stencil reads hit resident data);
//! - ResReu windows at a given step tile the interior exactly (no
//!   redundant compute), SO2DR windows overlap by `2*(S-s)*r` rows
//!   (measured redundant compute matches the closed form).

pub mod decomp;
pub mod plan;

pub use decomp::{Decomposition, Decomposition2d, DeviceAssignment, DeviceCaps, TilingConfig};
pub use plan::{
    apply_codec_policy, ChunkEpochPlan, DecompMode, EpochPlan, KernelInvocation, RegionOp,
    ResidencyConfig, ResidencySummary, ResidentMode, Scheme,
};
