//! Epoch plans: the schedule IR produced by the coordinator and consumed by
//! both interpreters (the real-numerics executor and the device simulator).
//!
//! Algorithm 1 of the paper maps onto this IR directly: an outer loop over
//! epochs (`N_t = ceil(n / k_off)`, last epoch possibly short), an inner
//! loop over chunks, and per chunk the op sequence
//! `HtoD -> RS read -> RS write -> kernels -> DtoH` (SO2DR) or
//! `HtoD -> (RS read/write + 1-step kernel) * steps -> DtoH` (ResReu).
//!
//! Every payload-carrying op addresses a [`Rect`] in global grid
//! coordinates. The 1-D row-band builders emit full-width rects (the
//! seed's spans, widened); the 2-D tile builder ([`so2dr_tiles_epoch`])
//! emits genuine sub-rects — strided column slices included — through
//! the *same* op vocabulary, so the executor, the flattener and the
//! codec policy need no tile-specific op kinds.

use super::decomp::{Decomposition, Decomposition2d, DeviceAssignment};
use crate::core::geom::{Rect, RowSpan};
use crate::stencil::StencilKind;
use crate::transfer::codec::{CodecKind, CompressMode};
use anyhow::{bail, Result};

/// Out-of-core sharing scheme.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Scheme {
    /// The paper's contribution: trapezoid sharing + redundant compute,
    /// multi-step (`k_on`) kernels.
    So2dr,
    /// Jin et al. 2013 baseline: intermediate-result reuse, single-step
    /// kernels.
    ResReu,
    /// Whole grid resident; no per-epoch transfers (paper §V-D).
    InCore,
}

impl Scheme {
    pub fn name(&self) -> &'static str {
        match self {
            Scheme::So2dr => "so2dr",
            Scheme::ResReu => "resreu",
            Scheme::InCore => "incore",
        }
    }

    pub fn parse(s: &str) -> Option<Scheme> {
        match s {
            "so2dr" => Some(Scheme::So2dr),
            "resreu" => Some(Scheme::ResReu),
            "incore" => Some(Scheme::InCore),
            _ => None,
        }
    }
}

/// Decomposition axis selection (`--decomp {rows,tiles}`): the classic
/// 1-D row-band split, or the 2-D row x column tiling whose halo volume
/// scales with tile perimeter.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DecompMode {
    /// 1-D row bands ([`Decomposition`]) — the paper's decomposition.
    #[default]
    Rows,
    /// 2-D tiles ([`Decomposition2d`]), `--chunks-x` x `--chunks-y`.
    Tiles,
}

impl DecompMode {
    pub fn name(&self) -> &'static str {
        match self {
            DecompMode::Rows => "rows",
            DecompMode::Tiles => "tiles",
        }
    }

    pub fn parse(s: &str) -> Option<DecompMode> {
        match s {
            "rows" => Some(DecompMode::Rows),
            "tiles" => Some(DecompMode::Tiles),
            _ => None,
        }
    }
}

/// A region-sharing copy (device-to-device) in global grid coordinates.
/// `time_step` is the epoch-local time index of the data being moved
/// (0 = epoch-start raw data) — used by tests to validate causality.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RegionOp {
    pub rect: Rect,
    pub time_step: usize,
}

/// One fused kernel launch: `windows[t]` is the compute rect of fused
/// step `t` (global coordinates, already clamped to the Dirichlet
/// interior on both axes). `first_step` is the 1-based epoch-local index
/// of the first fused step. `kind` is the stencil the launch applies —
/// recorded by the builder so interpreters dispatch per op instead of
/// carrying a run-wide kind out of band (which is what lets epochs of
/// *different* kinds chain in one resident run — the multi-stencil
/// pipeline).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct KernelInvocation {
    pub first_step: usize,
    pub windows: Vec<Rect>,
    pub kind: StencilKind,
}

impl KernelInvocation {
    pub fn fused_steps(&self) -> usize {
        self.windows.len()
    }

    /// Total compute area in cells (summed over fused steps).
    pub fn window_area(&self) -> usize {
        self.windows.iter().map(|w| w.area()).sum()
    }
}

/// One operation in a chunk's epoch sequence.
///
/// Transfer ops (`HtoD`/`DtoH`/`Evict`/`D2D`) carry a [`CodecKind`]:
/// the codec the payload crosses its channel under. Epoch builders
/// always emit [`CodecKind::Identity`]; [`apply_codec_policy`] retags
/// plans according to the surface-level [`CompressMode`], so both
/// interpreters execute/price exactly the same codec decisions.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ChunkOp {
    HtoD { rect: Rect, codec: CodecKind },
    RsRead(RegionOp),
    RsWrite(RegionOp),
    /// Resident-model marker: the chunk's settled `rect` is already on
    /// device from a previous epoch — no transfer. The executor checks the
    /// arena is live; the flattener emits no op (zero traffic), only the
    /// cross-epoch lifetime it implies.
    Resident { rect: Rect },
    /// Resident-model epoch-start halo refresh: read a neighbor's settled
    /// region (published via [`ChunkOp::RsWrite`], bridged by
    /// [`ChunkOp::D2D`] when the publisher is remote) from this device's
    /// sharing buffer instead of transferring it from the host. Same
    /// mechanics as `RsRead`, counted separately as cross-epoch traffic.
    Fetch(RegionOp),
    /// Resident-model capacity spill: write the settled `rect` back to the
    /// host and release the chunk's arena. The next epoch re-fetches it
    /// with an `HtoD` of the same rect (the host copy is fresh by
    /// construction — settled rects partition the grid).
    Evict { rect: Rect, codec: CodecKind },
    /// Peer-to-peer halo exchange: move the `(rect, time_step)` region
    /// just published by this chunk's `RsWrite` from `src_dev`'s sharing
    /// buffer to `dst_dev`'s, across the inter-device link. Emitted only
    /// when the producing and consuming chunks live on different devices;
    /// the consumer's `RsRead` then hits its own device's buffer.
    ///
    /// Naming note: this is the *inter-device* transfer — the flattener
    /// maps it to `OpKind::P2p`, priced by the link channel. It is
    /// unrelated to `OpKind::D2D`, which is the *on-device* sharing copy
    /// produced by `RsWrite`/`RsRead` (the paper's "O/D" category).
    D2D { src_dev: usize, dst_dev: usize, rect: Rect, time_step: usize, codec: CodecKind },
    Kernel(KernelInvocation),
    DtoH { rect: Rect, codec: CodecKind },
}

/// All ops of one chunk within one epoch, in execution order.
#[derive(Debug, Clone)]
pub struct ChunkEpochPlan {
    pub chunk: usize,
    /// Device the chunk is sharded onto (0 for single-device runs).
    pub device: usize,
    pub ops: Vec<ChunkOp>,
    /// Builder-recorded pass boundaries into `ops` (first 0, last
    /// `ops.len()`): under the resident execution model, every chunk's
    /// pass `p` ops (`pass_bounds[p]..pass_bounds[p + 1]`) complete
    /// before any chunk's pass `p + 1` ops run, because inter-epoch halo
    /// data flows both up and down the chunk order. Staged epochs record
    /// the trivial `[0, ops.len()]` (one chunk-major pass). These
    /// boundaries are *authoritative*: the builder records what it
    /// knows, and both interpreters read them through
    /// [`EpochPlan::pass_sequences`] instead of re-deriving the round
    /// structure from op patterns ([`resident_pass_bounds`] survives
    /// only as a debug-assert cross-check on the shapes it provably
    /// detects).
    pub pass_bounds: Vec<usize>,
}

/// One epoch: `steps` TB steps (`k'_off` in Algorithm 1) across all chunks.
#[derive(Debug, Clone)]
pub struct EpochPlan {
    pub scheme: Scheme,
    /// Stencil kind every kernel of this epoch applies — recorded at
    /// build time so a run may chain epochs of different kinds (the
    /// multi-stencil pipeline) without out-of-band plumbing. Kernel ops
    /// carry the same kind per invocation.
    pub kind: StencilKind,
    /// Epoch-local number of TB steps (`k'_off`).
    pub steps: usize,
    /// First global time-step index covered by this epoch (0-based).
    pub start_step: usize,
    /// Devices the epoch is sharded over (1 = the seed's single-GPU plan).
    pub n_devices: usize,
    /// True when this epoch belongs to a resident-model run: chunk arenas
    /// persist across epoch boundaries (per-chunk, fixed base), ops may
    /// include [`ChunkOp::Resident`]/[`ChunkOp::Fetch`]/[`ChunkOp::Evict`],
    /// and both interpreters execute the epoch in the builder-recorded
    /// passes ([`ChunkEpochPlan::pass_bounds`]) — all epoch-start
    /// publishes before any fetch/kernel.
    pub resident: bool,
    pub chunks: Vec<ChunkEpochPlan>,
}

/// Number of leading ops of a chunk-epoch forming its *arrival + publish*
/// phase (phase A) under the resident execution model: the residency
/// marker or host re-fetch, plus the epoch-start region publishes (and
/// their link transfers). Resident epochs are executed in two phases —
/// every chunk's phase A before any chunk's phase B — because fetches may
/// consume publishes of *later* chunks (data flows both up and down the
/// chunk order), which a single chunk-major sweep cannot order.
/// The take-while is safe on staged epochs too: any `RsWrite` it admits
/// precedes the chunk's first kernel in its own op order, so it only ever
/// extracts epoch-start data.
pub fn phase_a_len(ops: &[ChunkOp]) -> usize {
    ops.iter()
        .take_while(|op| {
            matches!(
                op,
                ChunkOp::Resident { .. }
                    | ChunkOp::HtoD { .. }
                    | ChunkOp::RsWrite(_)
                    | ChunkOp::D2D { .. }
            )
        })
        .count()
}

/// Structural *cross-check* for [`ChunkEpochPlan::pass_bounds`]: derive
/// the pass boundaries of a resident chunk-epoch from its op patterns.
///
/// Interpreters no longer consult this — the builder records the
/// boundaries it knows into the IR, and execution reads
/// [`EpochPlan::pass_sequences`]. The detector survives only as a
/// debug-assert in the builders, on the shapes it provably detects:
/// 1-D resident epochs (two passes, split at [`phase_a_len`]), staged
/// epochs converted to resident epoch 0 (two passes), and SO2DR
/// resident *tile* epochs (three passes — a publish run between two
/// fetch runs). It provably **mis-detects** ResReu resident tile
/// epochs: a first-row tile has an empty row-publish round, so its
/// south fetch merges into the column-fetch run and the shape collapses
/// to two passes — a causality hazard had execution trusted it, and the
/// concrete reason pass structure is builder-recorded now.
pub fn resident_pass_bounds(ops: &[ChunkOp]) -> Vec<usize> {
    let a = phase_a_len(ops);
    let mut k = a;
    while k < ops.len() && matches!(ops[k], ChunkOp::Fetch(_)) {
        k += 1;
    }
    let mut m = k;
    while m < ops.len() && matches!(ops[m], ChunkOp::RsWrite(_) | ChunkOp::D2D { .. }) {
        m += 1;
    }
    if k > a && m > k && m < ops.len() && matches!(ops[m], ChunkOp::Fetch(_)) {
        vec![0, a, m, ops.len()]
    } else {
        vec![0, a, ops.len()]
    }
}

/// *Detector-derived* pass-major order of one resident epoch — the
/// structural counterpart of [`EpochPlan::pass_sequences`], kept for
/// tests that cross-check the recorded boundaries against the op
/// grammar. Execution reads the recorded boundaries, never this.
pub fn resident_pass_sequences(plan: &EpochPlan) -> Vec<Vec<(usize, std::ops::Range<usize>)>> {
    let bounds: Vec<Vec<usize>> =
        plan.chunks.iter().map(|cp| resident_pass_bounds(&cp.ops)).collect();
    let n_passes = bounds.iter().map(|b| b.len() - 1).max().unwrap_or(1);
    (0..n_passes)
        .map(|pass| {
            bounds
                .iter()
                .enumerate()
                .filter(|(_, b)| pass + 1 < b.len())
                .map(|(ci, b)| (ci, b[pass]..b[pass + 1]))
                .collect()
        })
        .collect()
}

impl EpochPlan {
    /// Iterate `(chunk_index_in_plan, op_index, op)` in the canonical
    /// sequential execution order (chunk-major).
    pub fn iter_ops(&self) -> impl Iterator<Item = (usize, usize, &ChunkOp)> {
        self.chunks
            .iter()
            .enumerate()
            .flat_map(|(ci, c)| c.ops.iter().enumerate().map(move |(oi, op)| (ci, oi, op)))
    }

    pub fn n_ops(&self) -> usize {
        self.chunks.iter().map(|c| c.ops.len()).sum()
    }

    /// Pass-major execution order of this epoch, read from the
    /// builder-recorded [`ChunkEpochPlan::pass_bounds`]: for each pass,
    /// the `(chunk_index_in_plan, op_range)` segments to run. Chunks
    /// whose op lists have fewer passes simply contribute nothing to
    /// the trailing ones. The real-numerics executor, the flattener and
    /// the causality tests all iterate this one structure, so the pass
    /// order cannot drift between the interpreters — and because the
    /// builder recorded it, no interpreter re-derives round structure
    /// from op patterns.
    pub fn pass_sequences(&self) -> Vec<Vec<(usize, std::ops::Range<usize>)>> {
        let n_passes =
            self.chunks.iter().map(|c| c.pass_bounds.len() - 1).max().unwrap_or(1);
        (0..n_passes)
            .map(|pass| {
                self.chunks
                    .iter()
                    .enumerate()
                    .filter(|(_, c)| pass + 1 < c.pass_bounds.len())
                    .map(|(ci, c)| (ci, c.pass_bounds[pass]..c.pass_bounds[pass + 1]))
                    .collect()
            })
            .collect()
    }
}

/// Build one SO2DR epoch (Algorithm 1 lines 4–16) of `steps` TB steps with
/// `k_on`-step fused kernels, sharded over `devs`. When the consumer of a
/// region share lives on another device, the share is followed by a
/// [`ChunkOp::D2D`] halo exchange over the inter-device link.
pub fn so2dr_epoch(
    dc: &Decomposition,
    devs: &DeviceAssignment,
    kind: StencilKind,
    steps: usize,
    k_on: usize,
    start_step: usize,
) -> EpochPlan {
    assert!(steps >= 1 && k_on >= 1);
    assert_eq!(devs.n_chunks(), dc.n_chunks(), "device assignment shape mismatch");
    debug_assert_eq!(kind.radius(), dc.radius(), "stencil kind disagrees with decomposition");
    dc.check(steps);
    let cols = dc.cols();
    let radius = dc.radius();
    let full = |s: RowSpan| Rect::from_spans(s, 0, cols);
    let win = |s: RowSpan| Rect::from_spans(s, radius, cols - radius);
    let mut chunks = Vec::with_capacity(dc.n_chunks());
    for i in 0..dc.n_chunks() {
        let mut ops = Vec::new();
        ops.push(ChunkOp::HtoD { rect: full(dc.so2dr_htod(i, steps)), codec: CodecKind::Identity });
        let rs_read = dc.so2dr_rs_read(i, steps);
        if !rs_read.is_empty() {
            ops.push(ChunkOp::RsRead(RegionOp { rect: full(rs_read), time_step: 0 }));
        }
        let rs_write = dc.so2dr_rs_write(i, steps);
        if !rs_write.is_empty() {
            ops.push(ChunkOp::RsWrite(RegionOp { rect: full(rs_write), time_step: 0 }));
            if devs.crosses_boundary(i) {
                ops.push(ChunkOp::D2D {
                    src_dev: devs.device_of(i),
                    dst_dev: devs.device_of(i + 1),
                    rect: full(rs_write),
                    time_step: 0,
                    codec: CodecKind::Identity,
                });
            }
        }
        // Lines 7–14: ceil(steps / k_on) kernels, the last possibly short.
        let mut s = 1usize;
        while s <= steps {
            let fused = k_on.min(steps - s + 1);
            let windows: Vec<Rect> =
                (0..fused).map(|t| win(dc.so2dr_window(i, steps, s + t))).collect();
            ops.push(ChunkOp::Kernel(KernelInvocation { first_step: s, windows, kind }));
            s += fused;
        }
        ops.push(ChunkOp::DtoH { rect: full(dc.so2dr_dtoh(i)), codec: CodecKind::Identity });
        let pass_bounds = vec![0, ops.len()];
        chunks.push(ChunkEpochPlan { chunk: i, device: devs.device_of(i), ops, pass_bounds });
    }
    EpochPlan {
        scheme: Scheme::So2dr,
        kind,
        steps,
        start_step,
        n_devices: devs.n_devices(),
        resident: false,
        chunks,
    }
}

/// Build one SO2DR epoch over a 2-D tile decomposition: the 4-neighbor
/// generalization of [`so2dr_epoch`]. Tiles are walked in row-major
/// order; each tile transfers its shifted HtoD rect, reads its north row
/// band and west column band (a strided slice of the producer's arena)
/// from the region-sharing buffer, publishes the matching south/east
/// bands for its higher-index neighbors — reads before writes, writes
/// before kernels, so only epoch-start data is ever shared — and runs
/// the 2-D trapezoid kernels. Corner data rides the row bands (see
/// [`Decomposition2d`]'s corner-ownership rule). Shares whose consumer
/// lives on another device of the tile→device assignment are bridged by
/// [`ChunkOp::D2D`] link hops, exactly as in 1-D.
///
/// Degenerate tilings reproduce the 1-D plans op-for-op: with
/// `tiles_x == 1` every emitted op equals the row-band epoch's
/// (`tile_plans_degenerate_to_row_plans` locks this in).
pub fn so2dr_tiles_epoch(
    dc: &Decomposition2d,
    devs: &DeviceAssignment,
    kind: StencilKind,
    steps: usize,
    k_on: usize,
    start_step: usize,
) -> EpochPlan {
    assert!(steps >= 1 && k_on >= 1);
    assert_eq!(devs.n_chunks(), dc.n_tiles(), "device assignment shape mismatch");
    debug_assert_eq!(kind.radius(), dc.radius(), "stencil kind disagrees with decomposition");
    dc.check(steps);
    let tx = dc.tiles_x();
    let mut chunks = Vec::with_capacity(dc.n_tiles());
    for t in 0..dc.n_tiles() {
        let (i, j) = dc.tile_rc(t);
        let mut ops = Vec::new();
        ops.push(ChunkOp::HtoD { rect: dc.so2dr_htod(t, steps), codec: CodecKind::Identity });
        // Reads from the lower-index neighbors (already swept).
        for rect in [dc.so2dr_read_north(t, steps), dc.so2dr_read_west(t, steps)] {
            if !rect.is_empty() {
                ops.push(ChunkOp::RsRead(RegionOp { rect, time_step: 0 }));
            }
        }
        // Publishes for the higher-index neighbors — epoch-start data,
        // extracted before any kernel of this tile overwrites it.
        let south = (i + 1 < dc.tiles_y()).then(|| (dc.so2dr_write_south(t, steps), t + tx));
        let east = (j + 1 < tx).then(|| (dc.so2dr_write_east(t, steps), t + 1));
        for (rect, consumer) in [south, east].into_iter().flatten() {
            if rect.is_empty() {
                continue;
            }
            ops.push(ChunkOp::RsWrite(RegionOp { rect, time_step: 0 }));
            if devs.device_of(t) != devs.device_of(consumer) {
                ops.push(ChunkOp::D2D {
                    src_dev: devs.device_of(t),
                    dst_dev: devs.device_of(consumer),
                    rect,
                    time_step: 0,
                    codec: CodecKind::Identity,
                });
            }
        }
        let mut s = 1usize;
        while s <= steps {
            let fused = k_on.min(steps - s + 1);
            let windows: Vec<Rect> =
                (0..fused).map(|u| dc.so2dr_window(t, steps, s + u)).collect();
            ops.push(ChunkOp::Kernel(KernelInvocation { first_step: s, windows, kind }));
            s += fused;
        }
        ops.push(ChunkOp::DtoH { rect: dc.so2dr_dtoh(t), codec: CodecKind::Identity });
        let pass_bounds = vec![0, ops.len()];
        chunks.push(ChunkEpochPlan { chunk: t, device: devs.device_of(t), ops, pass_bounds });
    }
    EpochPlan {
        scheme: Scheme::So2dr,
        kind,
        steps,
        start_step,
        n_devices: devs.n_devices(),
        resident: false,
        chunks,
    }
}

/// Build one ResReu epoch over a 2-D tile decomposition: the product of
/// two 1-D skews (see the [`Decomposition2d`] ResReu rect algebra).
/// Tiles are walked in row-major order; each tile transfers exactly its
/// owned rect HtoD, and per TB step reads its west band, publishes its
/// south and east bands (time `s-1` data, extracted before its step-`s`
/// kernel), reads its north band, and runs one single-step skewed
/// kernel. Reading west *before* publishing south keeps the `2r x 2r`
/// corner cascade causal in a single chunk-major sweep. Shares whose
/// consumer lives on another device are bridged by [`ChunkOp::D2D`]
/// link hops immediately after their `RsWrite`, exactly as in 1-D.
///
/// Degenerate tilings reproduce the 1-D [`resreu_epoch`] op-for-op:
/// with `tiles_x == 1` the west/east bands are empty and each step's op
/// run is literally `RsWrite -> RsRead -> Kernel`
/// (`resreu_tile_plans_degenerate_to_row_plans` locks this in).
pub fn resreu_tiles_epoch(
    dc: &Decomposition2d,
    devs: &DeviceAssignment,
    kind: StencilKind,
    steps: usize,
    start_step: usize,
) -> EpochPlan {
    assert!(steps >= 1);
    assert_eq!(devs.n_chunks(), dc.n_tiles(), "device assignment shape mismatch");
    debug_assert_eq!(kind.radius(), dc.radius(), "stencil kind disagrees with decomposition");
    dc.check(steps);
    let (ty, tx) = (dc.tiles_y(), dc.tiles_x());
    let mut chunks = Vec::with_capacity(dc.n_tiles());
    for t in 0..dc.n_tiles() {
        let (i, j) = dc.tile_rc(t);
        let mut ops = Vec::new();
        ops.push(ChunkOp::HtoD { rect: dc.resreu_htod(t), codec: CodecKind::Identity });
        for s in 1..=steps {
            // Read the west band (time s-1) from (i, j-1) *first*: the
            // south band published next includes west-corner cells that
            // just arrived through it.
            let west = dc.resreu_read_west(t, s);
            if !west.is_empty() {
                ops.push(ChunkOp::RsRead(RegionOp { rect: west, time_step: s - 1 }));
            }
            // Publish the south/east bands for the higher-index
            // neighbors before this step's kernel overwrites them.
            let south = (i + 1 < ty).then(|| (dc.resreu_write_south(t, s), t + tx));
            let east = (j + 1 < tx).then(|| (dc.resreu_write_east(t, s), t + 1));
            for (rect, consumer) in [south, east].into_iter().flatten() {
                if rect.is_empty() {
                    continue;
                }
                ops.push(ChunkOp::RsWrite(RegionOp { rect, time_step: s - 1 }));
                if devs.device_of(t) != devs.device_of(consumer) {
                    ops.push(ChunkOp::D2D {
                        src_dev: devs.device_of(t),
                        dst_dev: devs.device_of(consumer),
                        rect,
                        time_step: s - 1,
                        codec: CodecKind::Identity,
                    });
                }
            }
            let north = dc.resreu_read_north(t, s);
            if !north.is_empty() {
                ops.push(ChunkOp::RsRead(RegionOp { rect: north, time_step: s - 1 }));
            }
            ops.push(ChunkOp::Kernel(KernelInvocation {
                first_step: s,
                windows: vec![dc.resreu_window(t, steps, s)],
                kind,
            }));
        }
        ops.push(ChunkOp::DtoH { rect: dc.resreu_dtoh(t, steps), codec: CodecKind::Identity });
        let pass_bounds = vec![0, ops.len()];
        chunks.push(ChunkEpochPlan { chunk: t, device: devs.device_of(t), ops, pass_bounds });
    }
    EpochPlan {
        scheme: Scheme::ResReu,
        kind,
        steps,
        start_step,
        n_devices: devs.n_devices(),
        resident: false,
        chunks,
    }
}

/// Split `n` steps into epochs of at most `s_tb` and build tile epoch
/// plans over `dc`. Both out-of-core sharing schemes generalize to
/// tiles (SO2DR as a product of trapezoids, ResReu as a product of
/// skews); only the in-core scheme — which has no decomposition at all
/// — is rejected here, at plan time, rather than silently mis-planned.
pub fn plan_run_tiles(
    scheme: Scheme,
    dc: &Decomposition2d,
    devs: &DeviceAssignment,
    kind: StencilKind,
    n: usize,
    s_tb: usize,
    k_on: usize,
) -> Result<Vec<EpochPlan>> {
    match scheme {
        Scheme::So2dr | Scheme::ResReu => {}
        Scheme::InCore => bail!(
            "the tiles decomposition is meaningless for incore (the whole grid is \
             resident; use --decomp rows)"
        ),
    }
    if n < 1 || s_tb < 1 || k_on < 1 {
        bail!("n, s_tb and k_on must be positive");
    }
    if !dc.feasible(s_tb.min(n)) {
        bail!(
            "infeasible tiling: skirt {} + r {} exceeds the minimum tile side {}x{} \
             (per-axis W_halo * S_TB <= D_chk, paper §IV-C)",
            dc.skirt(s_tb.min(n)),
            dc.radius(),
            dc.min_tile_rows(),
            dc.min_tile_cols()
        );
    }
    let mut plans = Vec::new();
    let mut done = 0usize;
    while done < n {
        let steps = s_tb.min(n - done);
        plans.push(match scheme {
            Scheme::So2dr => so2dr_tiles_epoch(dc, devs, kind, steps, k_on, done),
            Scheme::ResReu => resreu_tiles_epoch(dc, devs, kind, steps, done),
            Scheme::InCore => unreachable!("rejected above"),
        });
        done += steps;
    }
    Ok(plans)
}

/// Build one ResReu epoch: single-step kernels interleaved with RS
/// reads/writes of intermediate results (paper Fig. 2b), sharded over
/// `devs` with per-step [`ChunkOp::D2D`] exchanges at device boundaries.
pub fn resreu_epoch(
    dc: &Decomposition,
    devs: &DeviceAssignment,
    kind: StencilKind,
    steps: usize,
    start_step: usize,
) -> EpochPlan {
    assert!(steps >= 1);
    assert_eq!(devs.n_chunks(), dc.n_chunks(), "device assignment shape mismatch");
    debug_assert_eq!(kind.radius(), dc.radius(), "stencil kind disagrees with decomposition");
    dc.check(steps);
    let cols = dc.cols();
    let radius = dc.radius();
    let full = |s: RowSpan| Rect::from_spans(s, 0, cols);
    let win = |s: RowSpan| Rect::from_spans(s, radius, cols - radius);
    let mut chunks = Vec::with_capacity(dc.n_chunks());
    for i in 0..dc.n_chunks() {
        let mut ops = Vec::new();
        ops.push(ChunkOp::HtoD { rect: full(dc.resreu_htod(i)), codec: CodecKind::Identity });
        for s in 1..=steps {
            // Write our trailing rows (time s-1) for the upper neighbor,
            // then read our lower halo (time s-1) from the lower neighbor.
            let w = dc.resreu_rs_write(i, s);
            if !w.is_empty() {
                ops.push(ChunkOp::RsWrite(RegionOp { rect: full(w), time_step: s - 1 }));
                if devs.crosses_boundary(i) {
                    ops.push(ChunkOp::D2D {
                        src_dev: devs.device_of(i),
                        dst_dev: devs.device_of(i + 1),
                        rect: full(w),
                        time_step: s - 1,
                        codec: CodecKind::Identity,
                    });
                }
            }
            let r = dc.resreu_rs_read(i, s);
            if !r.is_empty() {
                ops.push(ChunkOp::RsRead(RegionOp { rect: full(r), time_step: s - 1 }));
            }
            ops.push(ChunkOp::Kernel(KernelInvocation {
                first_step: s,
                windows: vec![win(dc.resreu_window(i, steps, s))],
                kind,
            }));
        }
        ops.push(ChunkOp::DtoH {
            rect: full(dc.resreu_dtoh(i, steps)),
            codec: CodecKind::Identity,
        });
        let pass_bounds = vec![0, ops.len()];
        chunks.push(ChunkEpochPlan { chunk: i, device: devs.device_of(i), ops, pass_bounds });
    }
    EpochPlan {
        scheme: Scheme::ResReu,
        kind,
        steps,
        start_step,
        n_devices: devs.n_devices(),
        resident: false,
        chunks,
    }
}

/// Build the in-core "epoch": the whole grid is one resident chunk and all
/// `steps` are applied as `k_on`-fused kernels over the full interior.
/// No HtoD/DtoH ops are emitted (the paper excludes the two one-time
/// transfers from the in-core measurements, §V-D).
///
/// Degenerate geometries are rejected with typed errors through the
/// same validated error path (and messages) as
/// [`Decomposition::try_new`]: a grid whose rows or cols do not exceed
/// the `2*radius` Dirichlet ring has no interior cell, and used to be
/// silently clamped to an empty compute window here instead of
/// refusing to plan.
pub fn try_incore_epoch(
    rows: usize,
    cols: usize,
    kind: StencilKind,
    steps: usize,
    k_on: usize,
    start_step: usize,
) -> Result<EpochPlan> {
    let radius = kind.radius();
    if steps == 0 {
        bail!("steps must be positive (got 0)");
    }
    if k_on == 0 {
        bail!("k_on must be positive (got 0)");
    }
    if radius == 0 {
        bail!("radius must be positive (got 0)");
    }
    for (extent, axis) in [(rows, "rows"), (cols, "cols")] {
        if extent <= 2 * radius {
            bail!(
                "{axis} extent {extent} must exceed the 2*radius = {} Dirichlet boundary ring \
                 (no interior cell would remain)",
                2 * radius
            );
        }
    }
    let interior = Rect::new(radius, rows - radius, radius, cols - radius);
    let mut ops = Vec::new();
    let mut s = 1usize;
    while s <= steps {
        let fused = k_on.min(steps - s + 1);
        ops.push(ChunkOp::Kernel(KernelInvocation {
            first_step: s,
            windows: vec![interior; fused],
            kind,
        }));
        s += fused;
    }
    let pass_bounds = vec![0, ops.len()];
    Ok(EpochPlan {
        scheme: Scheme::InCore,
        kind,
        steps,
        start_step,
        n_devices: 1,
        resident: false,
        chunks: vec![ChunkEpochPlan { chunk: 0, device: 0, ops, pass_bounds }],
    })
}

/// Panicking [`try_incore_epoch`] (the original constructor contract,
/// kept for infallible call sites — planners whose inputs were already
/// validated by [`Decomposition::try_new`] or the config layer). The
/// panic message is the validated error, not a bare assert.
pub fn incore_epoch(
    rows: usize,
    cols: usize,
    kind: StencilKind,
    steps: usize,
    k_on: usize,
    start_step: usize,
) -> EpochPlan {
    try_incore_epoch(rows, cols, kind, steps, k_on, start_step)
        .unwrap_or_else(|e| panic!("invalid in-core epoch: {e}"))
}

/// Split a total of `n` steps into epochs of at most `s_tb` (Algorithm 1
/// lines 1–3) and build the per-epoch plans, sharded over `devs`. The
/// in-core scheme is inherently single-device and ignores the assignment.
pub fn plan_run_devices(
    scheme: Scheme,
    dc: &Decomposition,
    devs: &DeviceAssignment,
    kind: StencilKind,
    n: usize,
    s_tb: usize,
    k_on: usize,
) -> Vec<EpochPlan> {
    assert!(n >= 1 && s_tb >= 1);
    let mut plans = Vec::new();
    let mut done = 0usize;
    while done < n {
        let steps = s_tb.min(n - done);
        let plan = match scheme {
            Scheme::So2dr => so2dr_epoch(dc, devs, kind, steps, k_on, done),
            Scheme::ResReu => resreu_epoch(dc, devs, kind, steps, done),
            Scheme::InCore => incore_epoch(dc.rows(), dc.cols(), kind, steps, k_on, done),
        };
        plans.push(plan);
        done += steps;
    }
    plans
}

/// Single-device [`plan_run_devices`] (the seed's original entry point).
pub fn plan_run(
    scheme: Scheme,
    dc: &Decomposition,
    kind: StencilKind,
    n: usize,
    s_tb: usize,
    k_on: usize,
) -> Vec<EpochPlan> {
    plan_run_devices(scheme, dc, &DeviceAssignment::single(dc.n_chunks()), kind, n, s_tb, k_on)
}

// -------------------------------------------------------------------
// Residency planning: device-resident multi-epoch pipelining.
//
// A staged run synchronizes every epoch through the host: full HtoD of
// every chunk at epoch start, full DtoH at epoch end, even when the same
// chunk lands on the same device next epoch. The residency planner
// replaces that assumption with explicit cross-epoch lifetimes: a chunk
// is transferred HtoD once on first touch, its arena stays live across
// epochs while per-device capacity allows, inter-epoch halo freshness is
// satisfied by neighbor-arena publishes/fetches (on-device copies, or
// P2P link transfers at shard boundaries), and only capacity victims
// spill (`Evict`) and re-fetch. HtoD traffic drops by roughly the epoch
// count when every chunk fits.
// -------------------------------------------------------------------

/// Resident-execution mode selected at the surface (`--resident`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ResidentMode {
    /// Staged epochs (host round trip every epoch) — the legacy model.
    Off,
    /// Keep chunks resident while the per-device capacity model allows;
    /// spill the rest each epoch.
    Auto,
    /// Keep every chunk resident regardless of the capacity model.
    Force,
}

impl ResidentMode {
    pub fn name(&self) -> &'static str {
        match self {
            ResidentMode::Off => "off",
            ResidentMode::Auto => "auto",
            ResidentMode::Force => "force",
        }
    }

    pub fn parse(s: &str) -> Option<ResidentMode> {
        match s {
            "off" => Some(ResidentMode::Off),
            "auto" => Some(ResidentMode::Auto),
            "force" => Some(ResidentMode::Force),
            _ => None,
        }
    }
}

/// Inputs of the residency planner.
#[derive(Debug, Clone)]
pub struct ResidencyConfig {
    pub mode: ResidentMode,
    /// Per-device memory capacity (bytes) the planner must respect in
    /// `Auto` mode; `None` means unconstrained.
    pub cap_per_device: Option<u64>,
    /// Streams per device. Reserved for staggered-arrival planning (a
    /// ROADMAP follow-on): the current two-phase execution holds every
    /// chunk arena across the epoch boundary, so the capacity model
    /// does not yet depend on it.
    pub n_strm: usize,
}

impl ResidencyConfig {
    pub fn off() -> Self {
        Self { mode: ResidentMode::Off, cap_per_device: None, n_strm: 1 }
    }

    pub fn force(n_strm: usize) -> Self {
        Self { mode: ResidentMode::Force, cap_per_device: None, n_strm }
    }

    pub fn auto(cap_per_device: u64, n_strm: usize) -> Self {
        Self { mode: ResidentMode::Auto, cap_per_device: Some(cap_per_device), n_strm }
    }
}

/// What the residency planner decided, for reporting and tests.
#[derive(Debug, Clone)]
pub struct ResidencySummary {
    /// False when the plan degenerated to the staged model (mode off,
    /// in-core scheme, or a single epoch — nothing to keep resident).
    pub enabled: bool,
    /// Per chunk: does its arena stay live across epoch boundaries?
    pub kept: Vec<bool>,
    /// True when every device's modeled demand fits the capacity (always
    /// true when no capacity was given). When false the plan still runs —
    /// non-pinned chunks spill — but the planner makes no peak-memory
    /// promise.
    pub fits: bool,
    /// Modeled worst-case device-memory demand per device (bytes).
    pub demand_per_device: Vec<u64>,
    /// `Evict` ops in the emitted plan (spills the run will perform).
    pub planned_spills: usize,
    /// HtoD bytes a staged run of the same configuration would move.
    pub staged_htod_bytes: u64,
    /// HtoD bytes the emitted plan moves (first touches + re-fetches).
    pub planned_htod_bytes: u64,
}

impl ResidencySummary {
    fn disabled(n_chunks: usize, htod_bytes: u64) -> Self {
        Self {
            enabled: false,
            kept: vec![false; n_chunks],
            fits: true,
            demand_per_device: Vec::new(),
            planned_spills: 0,
            staged_htod_bytes: htod_bytes,
            planned_htod_bytes: htod_bytes,
        }
    }

    /// Host-transfer bytes the residency plan avoids vs the staged model.
    pub fn saved_htod_bytes(&self) -> u64 {
        self.staged_htod_bytes.saturating_sub(self.planned_htod_bytes)
    }
}

fn htod_bytes_of(plans: &[EpochPlan]) -> u64 {
    plans
        .iter()
        .flat_map(|p| p.iter_ops())
        .map(|(_, _, op)| match op {
            ChunkOp::HtoD { rect, .. } => rect.bytes_f32(),
            _ => 0,
        })
        .sum()
}

/// Retag every transfer op of `plans` with the codec the surface-level
/// policy selects (epoch builders always emit [`CodecKind::Identity`]).
/// Host transfers (`HtoD`/`DtoH`/`Evict`) follow
/// [`CompressMode::host_codec`]; inter-device hops (`D2D`) follow
/// [`CompressMode::link_codec`], which never selects a lossy codec —
/// halo regions are re-published every epoch, so quantization error
/// would compound instead of staying one-round-trip-bounded. Applied as
/// a post-pass so the real-numerics executor and the DES interpret the
/// same codec decisions, and so the 2-D tile plans' strided hops are
/// tagged exactly like any other transfer (payload size is the rect
/// area — the policy needs no decomposition handle).
pub fn apply_codec_policy(plans: &mut [EpochPlan], mode: CompressMode) {
    if mode == CompressMode::Off {
        return; // builders already emitted identity everywhere
    }
    for plan in plans.iter_mut() {
        for cp in plan.chunks.iter_mut() {
            for op in cp.ops.iter_mut() {
                match op {
                    ChunkOp::HtoD { rect, codec }
                    | ChunkOp::DtoH { rect, codec }
                    | ChunkOp::Evict { rect, codec } => {
                        *codec = mode.host_codec(rect.bytes_f32());
                    }
                    ChunkOp::D2D { rect, codec, .. } => {
                        *codec = mode.link_codec(rect.bytes_f32());
                    }
                    _ => {}
                }
            }
        }
    }
}

/// Build one resident-model epoch: chunks arrive with their previous
/// epoch's settled span on device (`kept`) or re-fetch it from the host
/// (spilled), publish the boundary rows their neighbors need into the
/// region-sharing buffer *before* any kernel runs, fetch their own
/// epoch-start skirt from the neighbors' publishes, compute, and finally
/// keep / spill (`Evict`) / write back (`DtoH`, final epoch only).
#[allow(clippy::too_many_arguments)]
fn resident_epoch(
    scheme: Scheme,
    dc: &Decomposition,
    devs: &DeviceAssignment,
    kind: StencilKind,
    steps: usize,
    k_on: usize,
    start_step: usize,
    prev_steps: usize,
    kept: &[bool],
    final_epoch: bool,
) -> EpochPlan {
    assert!(steps >= 1 && k_on >= 1 && prev_steps >= 1);
    assert_eq!(devs.n_chunks(), dc.n_chunks(), "device assignment shape mismatch");
    debug_assert_eq!(kind.radius(), dc.radius(), "stencil kind disagrees with decomposition");
    dc.check(steps);
    let d = dc.n_chunks();
    let cols = dc.cols();
    let radius = dc.radius();
    let full = |s: RowSpan| Rect::from_spans(s, 0, cols);
    let win = |s: RowSpan| Rect::from_spans(s, radius, cols - radius);
    // Fetch span a chunk needs at epoch start, beyond its settled rows.
    let fetch_low = |i: usize| -> RowSpan {
        match scheme {
            Scheme::So2dr => dc.so2dr_fetch_low(i, steps),
            _ => RowSpan::empty(),
        }
    };
    let fetch_high = |i: usize| -> RowSpan {
        match scheme {
            Scheme::So2dr => dc.so2dr_fetch_high(i, steps),
            Scheme::ResReu => dc.resreu_fetch(i, prev_steps),
            Scheme::InCore => RowSpan::empty(),
        }
    };
    let mut chunks = Vec::with_capacity(d);
    for i in 0..d {
        let settled_prev = dc.settled(scheme, i, prev_steps);
        let mut ops = Vec::new();
        // Phase A: arrive (marker or host re-fetch), then publish the
        // regions the neighbors will fetch — epoch-start data, extracted
        // before any kernel of this epoch overwrites it.
        if kept[i] {
            ops.push(ChunkOp::Resident { rect: full(settled_prev) });
        } else {
            ops.push(ChunkOp::HtoD { rect: full(settled_prev), codec: CodecKind::Identity });
        }
        // This chunk settles the lower neighbor's upper fetch span and
        // the upper neighbor's lower fetch span.
        if i > 0 {
            let span = fetch_high(i - 1);
            if !span.is_empty() {
                ops.push(ChunkOp::RsWrite(RegionOp { rect: full(span), time_step: 0 }));
                if devs.device_of(i) != devs.device_of(i - 1) {
                    ops.push(ChunkOp::D2D {
                        src_dev: devs.device_of(i),
                        dst_dev: devs.device_of(i - 1),
                        rect: full(span),
                        time_step: 0,
                        codec: CodecKind::Identity,
                    });
                }
            }
        }
        if i + 1 < d {
            let span = fetch_low(i + 1);
            if !span.is_empty() {
                ops.push(ChunkOp::RsWrite(RegionOp { rect: full(span), time_step: 0 }));
                if devs.device_of(i) != devs.device_of(i + 1) {
                    ops.push(ChunkOp::D2D {
                        src_dev: devs.device_of(i),
                        dst_dev: devs.device_of(i + 1),
                        rect: full(span),
                        time_step: 0,
                        codec: CodecKind::Identity,
                    });
                }
            }
        }
        // Phase B: fetch this chunk's own epoch-start skirt, compute,
        // retire. The phase boundary is recorded here — the builder
        // knows it; no interpreter re-detects it.
        let phase_a = ops.len();
        for span in [fetch_low(i), fetch_high(i)] {
            if !span.is_empty() {
                ops.push(ChunkOp::Fetch(RegionOp { rect: full(span), time_step: 0 }));
            }
        }
        match scheme {
            Scheme::So2dr => {
                let mut s = 1usize;
                while s <= steps {
                    let fused = k_on.min(steps - s + 1);
                    let windows: Vec<Rect> =
                        (0..fused).map(|t| win(dc.so2dr_window(i, steps, s + t))).collect();
                    ops.push(ChunkOp::Kernel(KernelInvocation { first_step: s, windows, kind }));
                    s += fused;
                }
            }
            Scheme::ResReu => {
                for s in 1..=steps {
                    let w = dc.resreu_rs_write(i, s);
                    if !w.is_empty() {
                        ops.push(ChunkOp::RsWrite(RegionOp {
                            rect: full(w),
                            time_step: s - 1,
                        }));
                        if devs.crosses_boundary(i) {
                            ops.push(ChunkOp::D2D {
                                src_dev: devs.device_of(i),
                                dst_dev: devs.device_of(i + 1),
                                rect: full(w),
                                time_step: s - 1,
                                codec: CodecKind::Identity,
                            });
                        }
                    }
                    let r = dc.resreu_rs_read(i, s);
                    if !r.is_empty() {
                        ops.push(ChunkOp::RsRead(RegionOp { rect: full(r), time_step: s - 1 }));
                    }
                    ops.push(ChunkOp::Kernel(KernelInvocation {
                        first_step: s,
                        windows: vec![win(dc.resreu_window(i, steps, s))],
                        kind,
                    }));
                }
            }
            Scheme::InCore => unreachable!("in-core runs are never resident-planned"),
        }
        let settled_now = dc.settled(scheme, i, steps);
        if final_epoch {
            ops.push(ChunkOp::DtoH { rect: full(settled_now), codec: CodecKind::Identity });
        } else if !kept[i] {
            ops.push(ChunkOp::Evict { rect: full(settled_now), codec: CodecKind::Identity });
        }
        let pass_bounds = vec![0, phase_a, ops.len()];
        debug_assert_eq!(
            resident_pass_bounds(&ops),
            pass_bounds,
            "structural pass detector disagrees with the recorded 1-D resident bounds"
        );
        chunks.push(ChunkEpochPlan { chunk: i, device: devs.device_of(i), ops, pass_bounds });
    }
    EpochPlan {
        scheme,
        kind,
        steps,
        start_step,
        n_devices: devs.n_devices(),
        resident: true,
        chunks,
    }
}

/// Convert a cloned staged epoch 0 into a resident plan's first epoch
/// (shared by the 1-D and tile residency planners): mark it resident
/// and replace each chunk's trailing `DtoH` by the planner's keep/spill
/// decision — dropped when the chunk's arena pins, an [`ChunkOp::Evict`]
/// of the same rect when it spills, kept as-is on a final epoch.
fn staged_epoch0_to_resident(staged: &EpochPlan, kept: &[bool], final_epoch: bool) -> EpochPlan {
    let mut plan = staged.clone();
    plan.resident = true;
    for cp in plan.chunks.iter_mut() {
        let Some(ChunkOp::DtoH { rect, codec }) = cp.ops.last().cloned() else {
            unreachable!("staged epochs end with DtoH");
        };
        if !final_epoch {
            cp.ops.pop();
            if !kept[cp.chunk] {
                cp.ops.push(ChunkOp::Evict { rect, codec });
            }
        }
        // Re-record the pass boundaries for resident execution: the
        // arrival transfer plus any publishes that precede this chunk's
        // first read/kernel form phase A (epoch-start data only — any
        // admitted `RsWrite` precedes the chunk's first kernel in its
        // own staged order). Staged epochs carry no `Fetch` ops, so the
        // structural detector provably agrees — cross-checked below.
        let phase_a = cp
            .ops
            .iter()
            .take_while(|op| {
                matches!(
                    op,
                    ChunkOp::HtoD { .. } | ChunkOp::RsWrite(_) | ChunkOp::D2D { .. }
                )
            })
            .count();
        cp.pass_bounds = vec![0, phase_a, cp.ops.len()];
        debug_assert_eq!(
            resident_pass_bounds(&cp.ops),
            cp.pass_bounds,
            "structural pass detector disagrees with the recorded epoch-0 bounds"
        );
    }
    plan
}

/// Plan a full run under the resident execution model. Returns the epoch
/// plans plus the planner's decisions. Falls back to the staged plan
/// (summary `enabled: false`) for `ResidentMode::Off`, the in-core
/// scheme, or single-epoch runs, where residency has nothing to save.
#[allow(clippy::too_many_arguments)]
pub fn plan_run_resident(
    scheme: Scheme,
    dc: &Decomposition,
    devs: &DeviceAssignment,
    kind: StencilKind,
    n: usize,
    s_tb: usize,
    k_on: usize,
    cfg: &ResidencyConfig,
) -> (Vec<EpochPlan>, ResidencySummary) {
    assert!(n >= 1 && s_tb >= 1);
    let staged = plan_run_devices(scheme, dc, devs, kind, n, s_tb, k_on);
    let staged_htod = htod_bytes_of(&staged);
    if cfg.mode == ResidentMode::Off || scheme == Scheme::InCore || staged.len() < 2 {
        let summary = ResidencySummary::disabled(dc.n_chunks(), staged_htod);
        return (staged, summary);
    }
    let s_max = staged.iter().map(|p| p.steps).max().unwrap();
    let buf_rows = dc.uniform_buffer_rows(scheme, s_max);
    let h_max = dc.skirt(s_max);
    let cap = match cfg.mode {
        ResidentMode::Force => None,
        _ => cfg.cap_per_device,
    };
    let keep_counts = devs.resident_keep_counts(dc, buf_rows, h_max, cap);
    let mut kept = vec![false; dc.n_chunks()];
    for dev in 0..devs.n_devices() {
        for (taken, i) in devs.chunks_on(dev).enumerate() {
            kept[i] = taken < keep_counts[dev];
        }
    }
    let demand_per_device: Vec<u64> = (0..devs.n_devices())
        .map(|dev| devs.resident_memory_demand(dc, dev, buf_rows, h_max))
        .collect();
    let fits = match cap {
        None => true,
        Some(cap) => demand_per_device.iter().all(|&d| d <= cap),
    };
    // Epoch 0 is the staged epoch (every chunk starts cold), with the
    // trailing DtoH replaced by the planner's keep/spill decision;
    // subsequent epochs are resident epochs.
    let mut plans = Vec::with_capacity(staged.len());
    let n_epochs = staged.len();
    let mut prev_steps = 0usize;
    for (e, p) in staged.iter().enumerate() {
        let final_epoch = e + 1 == n_epochs;
        let plan = if e == 0 {
            staged_epoch0_to_resident(p, &kept, final_epoch)
        } else {
            resident_epoch(
                scheme,
                dc,
                devs,
                kind,
                p.steps,
                k_on,
                p.start_step,
                prev_steps,
                &kept,
                final_epoch,
            )
        };
        prev_steps = p.steps;
        plans.push(plan);
    }
    let planned_spills = plans
        .iter()
        .flat_map(|p| p.iter_ops())
        .filter(|(_, _, op)| matches!(op, ChunkOp::Evict { .. }))
        .count();
    let planned_htod = htod_bytes_of(&plans);
    let summary = ResidencySummary {
        enabled: true,
        kept,
        fits,
        demand_per_device,
        planned_spills,
        staged_htod_bytes: staged_htod,
        planned_htod_bytes: planned_htod,
    };
    (plans, summary)
}

/// Plan a multi-stencil pipeline under the resident execution model,
/// chaining per-chunk arenas *across segment boundaries*: the grid is
/// transferred HtoD once on first touch and stays device-resident while
/// the stencil kind changes under it, because SO2DR's settled span is
/// the owned span — radius-independent — so segment `k+1`'s epoch-start
/// skirt is a neighbor-arena fetch, not a host round trip.
///
/// `segments` is `(kind, steps, seg_tb)` per stage; each segment is
/// split into epochs of at most `seg_tb` (already clamped to the
/// segment's feasibility by the caller). The scheme is SO2DR by
/// construction — ResReu's settled span depends on the epoch's step
/// count *and* radius, so its arenas cannot survive a radius change.
///
/// Capacity is all-or-nothing worst-case: a chunk pins only if every
/// segment's working set admits it (per-device demand is the max over
/// segments, since the arena must hold the largest skirt that will ever
/// address it). With `ResidentMode::Off` the plan degenerates to the
/// concatenated staged segments (summary `enabled: false`) — the same
/// host-round-trip-per-epoch behavior as running the segments back to
/// back.
///
/// Execution note: the returned plans mix radii, so they must run under
/// a *covering* [`Decomposition`] built with the pipeline's maximum
/// radius — its resident base sits at or below every segment's lowest
/// skirt row, and its uniform buffer height covers every segment's
/// arena (chunk bounds are radius-independent, so all segments agree on
/// owned spans).
pub fn plan_pipeline_resident(
    rows: usize,
    cols: usize,
    d: usize,
    devs: &DeviceAssignment,
    segments: &[(StencilKind, usize, usize)],
    k_on: usize,
    cfg: &ResidencyConfig,
) -> Result<(Vec<EpochPlan>, ResidencySummary)> {
    if segments.is_empty() {
        bail!("empty pipeline");
    }
    if k_on == 0 {
        bail!("k_on must be positive (got 0)");
    }
    // Per-segment decompositions and staged epoch splits. Chunk bounds
    // depend only on rows/d, so every segment agrees on owned spans;
    // only the skirt geometry differs.
    let mut dcs = Vec::with_capacity(segments.len());
    let mut staged_segs: Vec<Vec<EpochPlan>> = Vec::with_capacity(segments.len());
    let mut offset = 0usize;
    for &(kind, steps, seg_tb) in segments {
        if steps == 0 || seg_tb == 0 {
            bail!("segment steps and S_TB must be positive (got {steps}, {seg_tb})");
        }
        let dc = Decomposition::try_new(rows, cols, d, kind.radius())?;
        if !dc.feasible(seg_tb.min(steps)) {
            bail!(
                "segment {} infeasible: skirt of S_TB = {} exceeds the chunk height",
                kind.name(),
                seg_tb.min(steps)
            );
        }
        assert_eq!(devs.n_chunks(), dc.n_chunks(), "device assignment shape mismatch");
        let mut staged = plan_run_devices(Scheme::So2dr, &dc, devs, kind, steps, seg_tb, k_on);
        // Re-base epoch starts to pipeline-global step indices so traces
        // and error contexts stay monotone across segment boundaries.
        for p in staged.iter_mut() {
            p.start_step += offset;
        }
        offset += steps;
        dcs.push(dc);
        staged_segs.push(staged);
    }
    let staged_htod: u64 = staged_segs.iter().map(|s| htod_bytes_of(s)).sum();
    let n_epochs: usize = staged_segs.iter().map(|s| s.len()).sum();
    if cfg.mode == ResidentMode::Off || n_epochs < 2 {
        let plans: Vec<EpochPlan> = staged_segs.into_iter().flatten().collect();
        return Ok((plans, ResidencySummary::disabled(d, staged_htod)));
    }
    let cap = match cfg.mode {
        ResidentMode::Force => None,
        _ => cfg.cap_per_device,
    };
    // A chunk pins only if it pins under *every* segment's working set;
    // demand per device is the max over segments.
    let mut kept = vec![true; d];
    let mut demand_per_device = vec![0u64; devs.n_devices()];
    for (k, dc) in dcs.iter().enumerate() {
        let s_max = staged_segs[k].iter().map(|p| p.steps).max().unwrap();
        let buf_rows = dc.uniform_buffer_rows(Scheme::So2dr, s_max);
        let h_max = dc.skirt(s_max);
        let keep_counts = devs.resident_keep_counts(dc, buf_rows, h_max, cap);
        for dev in 0..devs.n_devices() {
            for (taken, i) in devs.chunks_on(dev).enumerate() {
                if taken >= keep_counts[dev] {
                    kept[i] = false;
                }
            }
            let demand = devs.resident_memory_demand(dc, dev, buf_rows, h_max);
            demand_per_device[dev] = demand_per_device[dev].max(demand);
        }
    }
    let fits = match cap {
        None => true,
        Some(cap) => demand_per_device.iter().all(|&d| d <= cap),
    };
    // One global epoch sequence: only the pipeline's very first epoch
    // stages every chunk cold; every later epoch — including each
    // subsequent segment's first — arrives resident, with `prev_steps`
    // threaded across the segment boundary so fetch spans line up with
    // what the previous epoch actually settled.
    let mut plans = Vec::with_capacity(n_epochs);
    let mut prev_steps = 0usize;
    let mut global_e = 0usize;
    for (k, staged) in staged_segs.iter().enumerate() {
        let (kind, _, _) = segments[k];
        for p in staged {
            let final_epoch = global_e + 1 == n_epochs;
            let plan = if global_e == 0 {
                staged_epoch0_to_resident(p, &kept, final_epoch)
            } else {
                resident_epoch(
                    Scheme::So2dr,
                    &dcs[k],
                    devs,
                    kind,
                    p.steps,
                    k_on,
                    p.start_step,
                    prev_steps,
                    &kept,
                    final_epoch,
                )
            };
            prev_steps = p.steps;
            plans.push(plan);
            global_e += 1;
        }
    }
    let planned_spills = plans
        .iter()
        .flat_map(|p| p.iter_ops())
        .filter(|(_, _, op)| matches!(op, ChunkOp::Evict { .. }))
        .count();
    let planned_htod = htod_bytes_of(&plans);
    let summary = ResidencySummary {
        enabled: true,
        kept,
        fits,
        demand_per_device,
        planned_spills,
        staged_htod_bytes: staged_htod,
        planned_htod_bytes: planned_htod,
    };
    Ok((plans, summary))
}

/// Append the publish — and, when the consumer lives on another device
/// of the tile→device assignment, the [`ChunkOp::D2D`] link hop — for
/// each `(rect, consumer)` band of a resident tile epoch.
fn push_publishes(
    ops: &mut Vec<ChunkOp>,
    devs: &DeviceAssignment,
    producer: usize,
    bands: [Option<(Rect, usize)>; 2],
) {
    for (rect, consumer) in bands.into_iter().flatten() {
        if rect.is_empty() {
            continue;
        }
        ops.push(ChunkOp::RsWrite(RegionOp { rect, time_step: 0 }));
        if devs.device_of(producer) != devs.device_of(consumer) {
            ops.push(ChunkOp::D2D {
                src_dev: devs.device_of(producer),
                dst_dev: devs.device_of(consumer),
                rect,
                time_step: 0,
                codec: CodecKind::Identity,
            });
        }
    }
}

/// Build one resident-model epoch over a 2-D tile decomposition: the
/// 4-neighbor generalization of [`resident_epoch`], for both sharing
/// schemes. Each tile arrives with its settled rect already on device
/// ([`ChunkOp::Resident`]) or re-fetches it from the host (spilled),
/// then refreshes the stale ring around it from its neighbors' arenas
/// in two publish/fetch rounds — column bands first, row bands second:
///
/// 1. publish the column bands the row neighbors fetch (settled data,
///    inside this tile's arena);
/// 2. fetch its own column bands, then publish the row bands — their
///    corner blocks arrived through the column fetches, so corners
///    cascade through the row bands exactly as in the staged tile
///    epochs instead of needing eight dedicated corner ops;
/// 3. fetch its own row bands, compute, and retire (keep /
///    [`ChunkOp::Evict`] / final-epoch `DtoH` of the settled rect).
///
/// SO2DR refreshes on all four sides (`h = steps * r` deep, the new
/// epoch's skirt); ResReu refreshes east and south only (`h' =
/// prev_steps * r` deep — the rows/cols the *previous* epoch's skew
/// shifted into the higher-index neighbors' arenas), with its per-step
/// bands flowing through the region-share buffer as in
/// [`resreu_tiles_epoch`].
///
/// Both interpreters execute the rounds as epoch-wide passes, read
/// from the **builder-recorded** [`ChunkEpochPlan::pass_bounds`]:
/// every tile's round-`k` ops before any tile's round `k + 1`, because
/// bands flow both up and down the row-major tile order along both
/// axes. A structurally empty round (no column round when
/// `tiles_x == 1`, no row round when `tiles_y == 1`) is merged away so
/// degenerate tilings record the 1-D two-pass shape and reproduce
/// [`resident_epoch`] op-for-op (locked by
/// `resident_tile_plans_degenerate_to_resident_row_plans`). The
/// recording is what makes ResReu tiles plannable at all: the
/// structural detector provably collapses a first-row tile's rounds
/// (empty row-publish run) into the wrong two-pass shape, so only
/// SO2DR shapes keep the debug-assert cross-check.
#[allow(clippy::too_many_arguments)]
fn resident_tiles_epoch(
    scheme: Scheme,
    dc: &Decomposition2d,
    devs: &DeviceAssignment,
    kind: StencilKind,
    steps: usize,
    k_on: usize,
    start_step: usize,
    prev_steps: usize,
    kept: &[bool],
    final_epoch: bool,
) -> EpochPlan {
    assert!(steps >= 1 && k_on >= 1 && prev_steps >= 1);
    assert_eq!(devs.n_chunks(), dc.n_tiles(), "device assignment shape mismatch");
    debug_assert_eq!(kind.radius(), dc.radius(), "stencil kind disagrees with decomposition");
    dc.check(steps);
    let (ty, tx) = (dc.tiles_y(), dc.tiles_x());
    let empty = Rect::new(0, 0, 0, 0);
    let mut chunks = Vec::with_capacity(dc.n_tiles());
    for t in 0..dc.n_tiles() {
        let (i, j) = dc.tile_rc(t);
        let settled_prev = dc.settled_for(scheme, t, prev_steps);
        let mut ops = Vec::new();
        if kept[t] {
            ops.push(ChunkOp::Resident { rect: settled_prev });
        } else {
            ops.push(ChunkOp::HtoD { rect: settled_prev, codec: CodecKind::Identity });
        }
        // Round 1: publish the column bands the row neighbors fetch.
        let col_pubs = match scheme {
            Scheme::So2dr => [
                (j > 0).then(|| (dc.resident_fetch_east(dc.index(i, j - 1), steps), t - 1)),
                (j + 1 < tx).then(|| (dc.resident_fetch_west(dc.index(i, j + 1), steps), t + 1)),
            ],
            Scheme::ResReu => [
                (j > 0).then(|| (dc.resreu_fetch_east(dc.index(i, j - 1), prev_steps), t - 1)),
                None,
            ],
            Scheme::InCore => unreachable!("in-core runs are never resident-planned"),
        };
        push_publishes(&mut ops, devs, t, col_pubs);
        let round1 = ops.len();
        // Round 2: fetch own column bands, then publish the row bands
        // (their corner blocks just arrived through the fetches).
        let col_fetches = match scheme {
            Scheme::So2dr => {
                [dc.resident_fetch_west(t, steps), dc.resident_fetch_east(t, steps)]
            }
            Scheme::ResReu => [empty, dc.resreu_fetch_east(t, prev_steps)],
            Scheme::InCore => unreachable!(),
        };
        for rect in col_fetches {
            if !rect.is_empty() {
                ops.push(ChunkOp::Fetch(RegionOp { rect, time_step: 0 }));
            }
        }
        let row_pubs = match scheme {
            Scheme::So2dr => [
                (i > 0).then(|| (dc.resident_fetch_south(dc.index(i - 1, j), steps), t - tx)),
                (i + 1 < ty).then(|| (dc.resident_fetch_north(dc.index(i + 1, j), steps), t + tx)),
            ],
            Scheme::ResReu => [
                (i > 0).then(|| (dc.resreu_fetch_south(dc.index(i - 1, j), prev_steps), t - tx)),
                None,
            ],
            Scheme::InCore => unreachable!(),
        };
        push_publishes(&mut ops, devs, t, row_pubs);
        let round2 = ops.len();
        // Round 3: fetch own row bands, compute, retire.
        let row_fetches = match scheme {
            Scheme::So2dr => {
                [dc.resident_fetch_north(t, steps), dc.resident_fetch_south(t, steps)]
            }
            Scheme::ResReu => [empty, dc.resreu_fetch_south(t, prev_steps)],
            Scheme::InCore => unreachable!(),
        };
        for rect in row_fetches {
            if !rect.is_empty() {
                ops.push(ChunkOp::Fetch(RegionOp { rect, time_step: 0 }));
            }
        }
        match scheme {
            Scheme::So2dr => {
                let mut s = 1usize;
                while s <= steps {
                    let fused = k_on.min(steps - s + 1);
                    let windows: Vec<Rect> =
                        (0..fused).map(|u| dc.so2dr_window(t, steps, s + u)).collect();
                    ops.push(ChunkOp::Kernel(KernelInvocation { first_step: s, windows, kind }));
                    s += fused;
                }
            }
            Scheme::ResReu => {
                for s in 1..=steps {
                    let west = dc.resreu_read_west(t, s);
                    if !west.is_empty() {
                        ops.push(ChunkOp::RsRead(RegionOp { rect: west, time_step: s - 1 }));
                    }
                    let south = (i + 1 < ty).then(|| (dc.resreu_write_south(t, s), t + tx));
                    let east = (j + 1 < tx).then(|| (dc.resreu_write_east(t, s), t + 1));
                    for (rect, consumer) in [south, east].into_iter().flatten() {
                        if rect.is_empty() {
                            continue;
                        }
                        ops.push(ChunkOp::RsWrite(RegionOp { rect, time_step: s - 1 }));
                        if devs.device_of(t) != devs.device_of(consumer) {
                            ops.push(ChunkOp::D2D {
                                src_dev: devs.device_of(t),
                                dst_dev: devs.device_of(consumer),
                                rect,
                                time_step: s - 1,
                                codec: CodecKind::Identity,
                            });
                        }
                    }
                    let north = dc.resreu_read_north(t, s);
                    if !north.is_empty() {
                        ops.push(ChunkOp::RsRead(RegionOp { rect: north, time_step: s - 1 }));
                    }
                    ops.push(ChunkOp::Kernel(KernelInvocation {
                        first_step: s,
                        windows: vec![dc.resreu_window(t, steps, s)],
                        kind,
                    }));
                }
            }
            Scheme::InCore => unreachable!(),
        }
        let settled_now = dc.settled_for(scheme, t, steps);
        if final_epoch {
            ops.push(ChunkOp::DtoH { rect: settled_now, codec: CodecKind::Identity });
        } else if !kept[t] {
            ops.push(ChunkOp::Evict { rect: settled_now, codec: CodecKind::Identity });
        }
        // Record the pass boundaries, merging structurally empty rounds
        // so degenerate tilings keep the 1-D two-pass shape.
        let pass_bounds = if tx == 1 {
            vec![0, round2, ops.len()]
        } else if ty == 1 {
            vec![0, round1, ops.len()]
        } else {
            vec![0, round1, round2, ops.len()]
        };
        if scheme == Scheme::So2dr {
            debug_assert_eq!(
                resident_pass_bounds(&ops),
                pass_bounds,
                "structural pass detector disagrees with the recorded tile bounds"
            );
        }
        chunks.push(ChunkEpochPlan { chunk: t, device: devs.device_of(t), ops, pass_bounds });
    }
    EpochPlan {
        scheme,
        kind,
        steps,
        start_step,
        n_devices: devs.n_devices(),
        resident: true,
        chunks,
    }
}

/// Plan a full 2-D tile run under the resident execution model: the
/// tile analog of [`plan_run_resident`], lifting the PR 4 "resident ×
/// tiles" composition rejection. Epoch 0 is the staged tile epoch
/// (every tile starts cold) with its trailing `DtoH` replaced by the
/// planner's keep/spill decision; later epochs are
/// [`resident_tiles_epoch`]s. Per-device capacity follows
/// [`DeviceAssignment::resident_tile_keep_counts`] (all-or-nothing per
/// device; spill victims re-fetch their settled rect). Falls back to
/// the staged tile plan (summary `enabled: false`) for
/// [`ResidentMode::Off`] or single-epoch runs; the in-core scheme and
/// infeasible tilings return the typed [`plan_run_tiles`] errors.
#[allow(clippy::too_many_arguments)]
pub fn plan_run_resident_tiles(
    scheme: Scheme,
    dc: &Decomposition2d,
    devs: &DeviceAssignment,
    kind: StencilKind,
    n: usize,
    s_tb: usize,
    k_on: usize,
    cfg: &ResidencyConfig,
) -> Result<(Vec<EpochPlan>, ResidencySummary)> {
    let staged = plan_run_tiles(scheme, dc, devs, kind, n, s_tb, k_on)?;
    let staged_htod = htod_bytes_of(&staged);
    if cfg.mode == ResidentMode::Off || staged.len() < 2 {
        let summary = ResidencySummary::disabled(dc.n_tiles(), staged_htod);
        return Ok((staged, summary));
    }
    let s_max = staged.iter().map(|p| p.steps).max().unwrap();
    let cap = match cfg.mode {
        ResidentMode::Force => None,
        _ => cfg.cap_per_device,
    };
    let keep_counts = devs.resident_tile_keep_counts(dc, s_max, cap);
    let mut kept = vec![false; dc.n_tiles()];
    for dev in 0..devs.n_devices() {
        for (taken, t) in devs.chunks_on(dev).enumerate() {
            kept[t] = taken < keep_counts[dev];
        }
    }
    let demand_per_device: Vec<u64> = (0..devs.n_devices())
        .map(|dev| devs.resident_tile_memory_demand(dc, dev, s_max))
        .collect();
    let fits = match cap {
        None => true,
        Some(cap) => demand_per_device.iter().all(|&d| d <= cap),
    };
    let n_epochs = staged.len();
    let mut plans = Vec::with_capacity(n_epochs);
    for (e, p) in staged.iter().enumerate() {
        let final_epoch = e + 1 == n_epochs;
        let plan = if e == 0 {
            staged_epoch0_to_resident(p, &kept, final_epoch)
        } else {
            let prev_steps = staged[e - 1].steps;
            resident_tiles_epoch(
                scheme,
                dc,
                devs,
                kind,
                p.steps,
                k_on,
                p.start_step,
                prev_steps,
                &kept,
                final_epoch,
            )
        };
        plans.push(plan);
    }
    let planned_spills = plans
        .iter()
        .flat_map(|p| p.iter_ops())
        .filter(|(_, _, op)| matches!(op, ChunkOp::Evict { .. }))
        .count();
    let planned_htod = htod_bytes_of(&plans);
    let summary = ResidencySummary {
        enabled: true,
        kept,
        fits,
        demand_per_device,
        planned_spills,
        staged_htod_bytes: staged_htod,
        planned_htod_bytes: planned_htod,
    };
    Ok((plans, summary))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dc() -> Decomposition {
        Decomposition::new(240, 64, 4, 2)
    }

    fn one_dev() -> DeviceAssignment {
        DeviceAssignment::single(4)
    }

    fn kind() -> StencilKind {
        StencilKind::Box { radius: 2 }
    }

    #[test]
    fn so2dr_epoch_structure() {
        let plan = so2dr_epoch(&dc(), &one_dev(), kind(), 8, 4, 0);
        assert_eq!(plan.chunks.len(), 4);
        let c1 = &plan.chunks[1];
        // HtoD, RsRead, RsWrite, 2 kernels (8/4), DtoH.
        assert_eq!(c1.ops.len(), 6);
        assert!(matches!(c1.ops[0], ChunkOp::HtoD { .. }));
        assert!(matches!(c1.ops[1], ChunkOp::RsRead(_)));
        assert!(matches!(c1.ops[2], ChunkOp::RsWrite(_)));
        assert!(matches!(c1.ops[3], ChunkOp::Kernel(_)));
        assert!(matches!(c1.ops[5], ChunkOp::DtoH { .. }));
        // First chunk has no RsRead; last no RsWrite.
        assert!(!plan.chunks[0].ops.iter().any(|o| matches!(o, ChunkOp::RsRead(_))));
        assert!(!plan.chunks[3].ops.iter().any(|o| matches!(o, ChunkOp::RsWrite(_))));
    }

    #[test]
    fn row_band_ops_are_full_width_rects() {
        let plan = so2dr_epoch(&dc(), &one_dev(), kind(), 8, 4, 0);
        for (_, _, op) in plan.iter_ops() {
            match op {
                ChunkOp::HtoD { rect, .. } | ChunkOp::DtoH { rect, .. } => {
                    assert_eq!((rect.c0, rect.c1), (0, 64), "{op:?}");
                }
                ChunkOp::RsRead(r) | ChunkOp::RsWrite(r) => {
                    assert_eq!((r.rect.c0, r.rect.c1), (0, 64));
                }
                ChunkOp::Kernel(k) => {
                    for w in &k.windows {
                        // Windows carry the Dirichlet column interior.
                        assert_eq!((w.c0, w.c1), (2, 62));
                    }
                }
                _ => {}
            }
        }
    }

    #[test]
    fn so2dr_residual_kernel() {
        let plan = so2dr_epoch(&dc(), &one_dev(), kind(), 7, 4, 0);
        let kernels: Vec<&KernelInvocation> = plan.chunks[0]
            .ops
            .iter()
            .filter_map(|o| match o {
                ChunkOp::Kernel(k) => Some(k),
                _ => None,
            })
            .collect();
        assert_eq!(kernels.len(), 2);
        assert_eq!(kernels[0].fused_steps(), 4);
        assert_eq!(kernels[1].fused_steps(), 3); // k'_off % k_on
        assert_eq!(kernels[1].first_step, 5);
        assert!(kernels[0].window_area() > 0);
    }

    #[test]
    fn resreu_epoch_structure() {
        let plan = resreu_epoch(&dc(), &one_dev(), kind(), 5, 0);
        let c1 = &plan.chunks[1];
        // HtoD + 5*(write+read+kernel) + DtoH
        assert_eq!(c1.ops.len(), 1 + 5 * 3 + 1);
        // All kernels single-step.
        for op in &c1.ops {
            if let ChunkOp::Kernel(k) = op {
                assert_eq!(k.fused_steps(), 1);
            }
        }
    }

    #[test]
    fn plan_run_epoch_split() {
        let plans = plan_run(Scheme::So2dr, &dc(), kind(), 20, 8, 4);
        assert_eq!(plans.len(), 3);
        assert_eq!(plans[0].steps, 8);
        assert_eq!(plans[2].steps, 4); // n % s_tb
        assert_eq!(plans[2].start_step, 16);
    }

    #[test]
    fn incore_plan_has_no_transfers() {
        let plans = plan_run(Scheme::InCore, &dc(), kind(), 10, 10, 4);
        assert_eq!(plans.len(), 1);
        for (_, _, op) in plans[0].iter_ops() {
            assert!(matches!(op, ChunkOp::Kernel(_)));
        }
        // ceil(10/4) = 3 kernels.
        assert_eq!(plans[0].n_ops(), 3);
    }

    #[test]
    fn resreu_causality_pairs() {
        // RsWrite(i, s) rect+time must equal RsRead(i+1, s).
        let plan = resreu_epoch(&dc(), &one_dev(), kind(), 5, 0);
        for i in 0..3 {
            let writes: Vec<&RegionOp> = plan.chunks[i]
                .ops
                .iter()
                .filter_map(|o| match o {
                    ChunkOp::RsWrite(r) => Some(r),
                    _ => None,
                })
                .collect();
            let reads: Vec<&RegionOp> = plan.chunks[i + 1]
                .ops
                .iter()
                .filter_map(|o| match o {
                    ChunkOp::RsRead(r) => Some(r),
                    _ => None,
                })
                .collect();
            assert_eq!(writes.len(), reads.len());
            for (w, r) in writes.iter().zip(&reads) {
                assert_eq!(w, r);
            }
        }
    }
}

#[cfg(test)]
mod codec_tests {
    use super::*;
    use crate::transfer::codec::AUTO_MIN_BYTES;

    fn count_codecs(plans: &[EpochPlan]) -> (usize, usize, usize) {
        let (mut host, mut lossy, mut lossless) = (0usize, 0usize, 0usize);
        for (_, _, op) in plans.iter().flat_map(|p| p.iter_ops()) {
            let codec = match op {
                ChunkOp::HtoD { codec, .. }
                | ChunkOp::DtoH { codec, .. }
                | ChunkOp::Evict { codec, .. } => {
                    host += 1;
                    *codec
                }
                ChunkOp::D2D { codec, .. } => *codec,
                _ => continue,
            };
            match codec {
                CodecKind::Bf16 => lossy += 1,
                CodecKind::Lossless => lossless += 1,
                CodecKind::Identity => {}
            }
        }
        (host, lossy, lossless)
    }

    #[test]
    fn builders_emit_identity_and_off_keeps_it() {
        let dc = Decomposition::new(240, 64, 4, 2);
        let devs = DeviceAssignment::contiguous(4, 2);
        let mut plans =
            plan_run_devices(Scheme::So2dr, &dc, &devs, StencilKind::Box { radius: 2 }, 16, 8, 4);
        let (host, lossy, lossless) = count_codecs(&plans);
        assert!(host > 0);
        assert_eq!((lossy, lossless), (0, 0));
        apply_codec_policy(&mut plans, CompressMode::Off);
        assert_eq!(count_codecs(&plans), (host, 0, 0));
    }

    #[test]
    fn bf16_policy_tags_host_ops_but_never_the_link() {
        let dc = Decomposition::new(240, 64, 4, 2);
        let devs = DeviceAssignment::contiguous(4, 4);
        let mut plans =
            plan_run_devices(Scheme::ResReu, &dc, &devs, StencilKind::Box { radius: 2 }, 10, 5, 1);
        apply_codec_policy(&mut plans, CompressMode::Bf16);
        for (_, _, op) in plans.iter().flat_map(|p| p.iter_ops()) {
            match op {
                ChunkOp::HtoD { codec, .. }
                | ChunkOp::DtoH { codec, .. }
                | ChunkOp::Evict { codec, .. } => assert_eq!(*codec, CodecKind::Bf16),
                ChunkOp::D2D { codec, .. } => {
                    assert_eq!(*codec, CodecKind::Identity, "halo hops never quantize")
                }
                _ => {}
            }
        }
    }

    #[test]
    fn lossless_policy_tags_every_transfer_including_resident_spills() {
        let dc = Decomposition::new(240, 64, 4, 2);
        let devs = DeviceAssignment::contiguous(4, 2);
        let (mut plans, _) = plan_run_resident(
            Scheme::So2dr,
            &dc,
            &devs,
            StencilKind::Box { radius: 2 },
            20,
            8,
            4,
            &ResidencyConfig::auto(1, 3), // tight cap: every epoch evicts
        );
        apply_codec_policy(&mut plans, CompressMode::Lossless);
        let mut evicts = 0;
        for (_, _, op) in plans.iter().flat_map(|p| p.iter_ops()) {
            match op {
                ChunkOp::HtoD { codec, .. } | ChunkOp::DtoH { codec, .. } => {
                    assert_eq!(*codec, CodecKind::Lossless)
                }
                ChunkOp::Evict { codec, .. } => {
                    evicts += 1;
                    assert_eq!(*codec, CodecKind::Lossless);
                }
                ChunkOp::D2D { codec, .. } => assert_eq!(*codec, CodecKind::Lossless),
                _ => {}
            }
        }
        assert!(evicts > 0, "tight cap must plan spills");
    }

    #[test]
    fn auto_policy_splits_on_payload_size() {
        // cols sized so a full-chunk transfer crosses the auto threshold
        // while the 2-row halo exchange stays under it.
        let rows = 64usize;
        let cols = (AUTO_MIN_BYTES as usize) / (4 * (rows / 4)) + 1;
        let dc = Decomposition::new(rows, cols, 4, 1);
        let devs = DeviceAssignment::contiguous(4, 4);
        let mut plans =
            plan_run_devices(Scheme::ResReu, &dc, &devs, StencilKind::Box { radius: 1 }, 4, 4, 1);
        apply_codec_policy(&mut plans, CompressMode::Auto);
        let (mut big_lossless, mut small_identity) = (false, false);
        for (_, _, op) in plans.iter().flat_map(|p| p.iter_ops()) {
            match op {
                ChunkOp::HtoD { rect, codec } | ChunkOp::DtoH { rect, codec } => {
                    if rect.bytes_f32() >= AUTO_MIN_BYTES {
                        assert_eq!(*codec, CodecKind::Lossless);
                        big_lossless = true;
                    } else {
                        assert_eq!(*codec, CodecKind::Identity);
                    }
                }
                ChunkOp::D2D { rect, codec, .. } => {
                    assert!(rect.bytes_f32() < AUTO_MIN_BYTES);
                    assert_eq!(*codec, CodecKind::Identity);
                    small_identity = true;
                }
                _ => {}
            }
        }
        assert!(big_lossless && small_identity, "both policy branches exercised");
    }

    #[test]
    fn tile_plan_hops_are_tagged_like_any_other() {
        // The codec post-pass needs no decomposition handle: the tile
        // plan's strided column hops are tagged by rect size alone.
        let dc = Decomposition2d::try_new(96, 96, 2, 2, 1).unwrap();
        let devs = DeviceAssignment::contiguous(4, 4);
        let mut plans =
            plan_run_tiles(Scheme::So2dr, &dc, &devs, StencilKind::Box { radius: 1 }, 8, 4, 2)
                .unwrap();
        apply_codec_policy(&mut plans, CompressMode::Lossless);
        let (host, _, lossless) = count_codecs(&plans);
        assert!(host > 0);
        let d2d = plans
            .iter()
            .flat_map(|p| p.iter_ops())
            .filter(|(_, _, op)| matches!(op, ChunkOp::D2D { .. }))
            .count();
        assert!(d2d > 0, "fully sharded tiling must exchange over the link");
        assert_eq!(lossless, host + d2d, "every transfer hop tagged");
    }
}

#[cfg(test)]
mod device_tests {
    use super::*;
    use std::collections::{HashMap, HashSet};

    fn dc() -> Decomposition {
        Decomposition::new(240, 64, 4, 2)
    }

    fn kind() -> StencilKind {
        StencilKind::Box { radius: 2 }
    }

    /// Walk a plan in canonical execution order and verify plan causality:
    /// - a chunk never publishes (`RsWrite`) data of a time step it has
    ///   not yet computed (`time_step <= kernel steps completed so far`);
    /// - a `D2D` only moves a region its source device already holds;
    /// - an `RsRead` only consumes a region available on the reader's own
    ///   device;
    /// - every region a kernel step depends on arrived before the kernel
    ///   (reads precede the kernel of their `first_step` in op order).
    fn check_causality(plan: &EpochPlan) {
        // (rect, time_step) -> devices holding the region.
        let mut available: HashMap<(Rect, usize), HashSet<usize>> = HashMap::new();
        // Walk ops in the true execution order: staged epochs run
        // chunk-major; resident epochs run pass-major (every chunk's
        // pass p before any chunk's pass p + 1 — two passes for 1-D
        // plans, three for resident tile plans), so a fetch is checked
        // against exactly the publishes that executed before it.
        let mut order: Vec<(usize, usize)> = Vec::new();
        if plan.resident {
            for segments in resident_pass_sequences(plan) {
                for (ci, range) in segments {
                    for oi in range {
                        order.push((ci, oi));
                    }
                }
            }
        } else {
            for (ci, cp) in plan.chunks.iter().enumerate() {
                for oi in 0..cp.ops.len() {
                    order.push((ci, oi));
                }
            }
        }
        let mut steps_done_of = vec![0usize; plan.chunks.len()];
        for (ci, oi) in order {
            let cp = &plan.chunks[ci];
            let steps_done = steps_done_of[ci];
            let op = &cp.ops[oi];
            match op {
                ChunkOp::RsWrite(r) => {
                    assert!(
                        r.time_step <= steps_done,
                        "chunk {} publishes t{} after only {} steps",
                        cp.chunk,
                        r.time_step,
                        steps_done
                    );
                    available.entry((r.rect, r.time_step)).or_default().insert(cp.device);
                }
                ChunkOp::D2D { src_dev, dst_dev, rect, time_step, .. } => {
                    assert_eq!(*src_dev, cp.device, "D2D source must be the producer");
                    assert_ne!(src_dev, dst_dev, "D2D must cross devices");
                    let holders = available
                        .get(&(*rect, *time_step))
                        .unwrap_or_else(|| panic!("D2D of unpublished region {rect}"));
                    assert!(
                        holders.contains(src_dev),
                        "D2D from dev {src_dev} which does not hold {rect} @t{time_step}"
                    );
                    available.entry((*rect, *time_step)).or_default().insert(*dst_dev);
                }
                ChunkOp::RsRead(r) => {
                    let holders =
                        available.get(&(r.rect, r.time_step)).unwrap_or_else(|| {
                            panic!(
                                "chunk {} reads unpublished region {} @t{}",
                                cp.chunk, r.rect, r.time_step
                            )
                        });
                    assert!(
                        holders.contains(&cp.device),
                        "chunk {} (dev {}) reads {} @t{} not on its device",
                        cp.chunk,
                        cp.device,
                        r.rect,
                        r.time_step
                    );
                    // Halo data must predate the steps it feeds.
                    assert!(
                        r.time_step <= steps_done,
                        "read of future time step t{}",
                        r.time_step
                    );
                }
                ChunkOp::Kernel(k) => {
                    assert_eq!(k.first_step, steps_done + 1, "kernel steps out of order");
                    steps_done_of[ci] += k.fused_steps();
                }
                ChunkOp::Fetch(r) => {
                    // A fetch is an RsRead of epoch-start data: its
                    // publisher must have run (in phase A) and the
                    // region must sit on the reader's device.
                    assert_eq!(r.time_step, 0, "fetches move epoch-start data");
                    assert_eq!(steps_done, 0, "fetches precede kernels");
                    let holders =
                        available.get(&(r.rect, r.time_step)).unwrap_or_else(|| {
                            panic!("chunk {} fetches unpublished region {}", cp.chunk, r.rect)
                        });
                    assert!(
                        holders.contains(&cp.device),
                        "chunk {} (dev {}) fetches {} not on its device",
                        cp.chunk,
                        cp.device,
                        r.rect
                    );
                }
                ChunkOp::Resident { .. } | ChunkOp::Evict { .. } => {
                    assert!(plan.resident, "resident ops only in resident plans");
                }
                ChunkOp::HtoD { .. } | ChunkOp::DtoH { .. } => {}
            }
        }
        for (ci, cp) in plan.chunks.iter().enumerate() {
            assert_eq!(steps_done_of[ci], plan.steps, "chunk {} step count", cp.chunk);
        }
    }

    #[test]
    fn so2dr_causality_across_device_counts() {
        for n_dev in [1, 2, 4] {
            let devs = DeviceAssignment::contiguous(4, n_dev);
            check_causality(&so2dr_epoch(&dc(), &devs, kind(), 8, 4, 0));
        }
    }

    #[test]
    fn resreu_causality_across_device_counts() {
        for n_dev in [1, 2, 4] {
            let devs = DeviceAssignment::contiguous(4, n_dev);
            check_causality(&resreu_epoch(&dc(), &devs, kind(), 5, 0));
        }
    }

    #[test]
    fn tile_causality_across_device_counts() {
        let dc = Decomposition2d::try_new(120, 96, 2, 3, 2).unwrap();
        for n_dev in [1, 2, 3, 6] {
            let devs = DeviceAssignment::contiguous(6, n_dev);
            check_causality(&so2dr_tiles_epoch(&dc, &devs, kind(), 4, 2, 0));
        }
    }

    #[test]
    fn resident_tile_causality_across_device_counts_and_caps() {
        // The load-bearing check of the 2-D settled/fetch algebra: every
        // fetch — corners cascading through the row bands included —
        // finds its publish on the right device under the pass-major
        // execution order, for pinned and spilling plans alike.
        let dc = Decomposition2d::try_new(120, 96, 2, 3, 2).unwrap();
        for n_dev in [1usize, 2, 3, 6] {
            let devs = DeviceAssignment::contiguous(6, n_dev);
            for cfg in [ResidencyConfig::force(3), ResidencyConfig::auto(1, 3)] {
                let (plans, _) =
                    plan_run_resident_tiles(Scheme::So2dr, &dc, &devs, kind(), 12, 4, 2, &cfg)
                        .unwrap();
                assert_eq!(plans.len(), 3);
                for plan in &plans {
                    check_causality(plan);
                }
            }
        }
    }

    #[test]
    fn d2d_emitted_exactly_at_device_boundaries() {
        let devs = DeviceAssignment::contiguous(4, 2); // boundary between chunks 1|2
        let plan = so2dr_epoch(&dc(), &devs, kind(), 8, 4, 0);
        for cp in &plan.chunks {
            let d2d: Vec<&ChunkOp> = cp
                .ops
                .iter()
                .filter(|o| matches!(o, ChunkOp::D2D { .. }))
                .collect();
            if cp.chunk == 1 {
                assert_eq!(d2d.len(), 1, "one raw-halo exchange per epoch at the boundary");
                if let ChunkOp::D2D { src_dev, dst_dev, rect, time_step, .. } = d2d[0] {
                    assert_eq!((*src_dev, *dst_dev, *time_step), (0, 1, 0));
                    assert_eq!(rect.rows(), dc().so2dr_rs_write(1, 8));
                }
            } else {
                assert!(d2d.is_empty(), "chunk {} must not exchange", cp.chunk);
            }
        }
    }

    #[test]
    fn tile_d2d_follows_the_tile_to_device_assignment() {
        // 2x2 tiles over 2 devices: tiles {0,1} on dev 0, {2,3} on dev 1.
        // Only the south shares (consumer t+tx) cross the boundary.
        let dc = Decomposition2d::try_new(96, 96, 2, 2, 1).unwrap();
        let devs = DeviceAssignment::contiguous(4, 2);
        let plan = so2dr_tiles_epoch(&dc, &devs, StencilKind::Box { radius: 1 }, 4, 2, 0);
        let mut crossings = Vec::new();
        for cp in &plan.chunks {
            for op in &cp.ops {
                if let ChunkOp::D2D { src_dev, dst_dev, rect, .. } = op {
                    crossings.push((cp.chunk, *src_dev, *dst_dev, *rect));
                }
            }
        }
        // Tiles 0 and 1 publish south bands to tiles 2 and 3.
        assert_eq!(crossings.len(), 2, "{crossings:?}");
        for (t, src, dst, rect) in &crossings {
            assert!(*t < 2);
            assert_eq!((*src, *dst), (0, 1));
            assert_eq!(*rect, dc.so2dr_write_south(*t, 4));
        }
        // East shares stay on-device (0->1 and 2->3 are same-device).
        let plan1 = so2dr_tiles_epoch(
            &dc,
            &DeviceAssignment::single(4),
            StencilKind::Box { radius: 1 },
            4,
            2,
            0,
        );
        assert!(plan1.iter_ops().all(|(_, _, op)| !matches!(op, ChunkOp::D2D { .. })));
    }

    #[test]
    fn resreu_d2d_one_per_step_at_boundary() {
        let devs = DeviceAssignment::contiguous(4, 4);
        let plan = resreu_epoch(&dc(), &devs, kind(), 5, 0);
        // Every non-last chunk crosses a boundary: one D2D per step.
        for cp in &plan.chunks {
            let n_d2d = cp.ops.iter().filter(|o| matches!(o, ChunkOp::D2D { .. })).count();
            if cp.chunk + 1 < 4 {
                assert_eq!(n_d2d, 5, "chunk {}", cp.chunk);
            } else {
                assert_eq!(n_d2d, 0);
            }
        }
    }

    #[test]
    fn d2d_follows_its_write_immediately() {
        let devs = DeviceAssignment::contiguous(4, 4);
        let dc2 = Decomposition2d::try_new(96, 96, 2, 2, 1).unwrap();
        for plan in [
            so2dr_epoch(&dc(), &devs, kind(), 6, 2, 0),
            resreu_epoch(&dc(), &devs, kind(), 5, 0),
            so2dr_tiles_epoch(&dc2, &devs, StencilKind::Box { radius: 1 }, 4, 2, 0),
        ] {
            for cp in &plan.chunks {
                for (oi, op) in cp.ops.iter().enumerate() {
                    if let ChunkOp::D2D { rect, time_step, .. } = op {
                        match &cp.ops[oi - 1] {
                            ChunkOp::RsWrite(r) => {
                                assert_eq!((r.rect, r.time_step), (*rect, *time_step));
                            }
                            other => panic!("D2D not preceded by its RsWrite: {other:?}"),
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn single_device_plans_have_no_d2d() {
        let devs = DeviceAssignment::single(4);
        for plan in [
            so2dr_epoch(&dc(), &devs, kind(), 8, 4, 0),
            resreu_epoch(&dc(), &devs, kind(), 5, 0),
        ] {
            assert_eq!(plan.n_devices, 1);
            for (_, _, op) in plan.iter_ops() {
                assert!(!matches!(op, ChunkOp::D2D { .. }));
            }
        }
    }

    fn count_ops(plans: &[EpochPlan], f: impl Fn(&ChunkOp) -> bool) -> usize {
        plans.iter().flat_map(|p| p.iter_ops()).filter(|&(_, _, op)| f(op)).count()
    }

    #[test]
    fn resident_force_transfers_each_chunk_once() {
        let dc = dc();
        for (scheme, k_on, n, s_tb) in [(Scheme::So2dr, 4, 20, 8), (Scheme::ResReu, 1, 15, 5)] {
            for n_dev in [1usize, 2, 4] {
                let devs = DeviceAssignment::contiguous(4, n_dev);
                let (plans, summary) = plan_run_resident(
                    scheme,
                    &dc,
                    &devs,
                    kind(),
                    n,
                    s_tb,
                    k_on,
                    &ResidencyConfig::force(3),
                );
                assert!(summary.enabled);
                assert!(summary.fits);
                assert!(summary.kept.iter().all(|&k| k));
                assert_eq!(summary.planned_spills, 0);
                // One HtoD per chunk (first touch), one DtoH per chunk
                // (final writeback), markers everywhere in between.
                assert_eq!(count_ops(&plans, |op| matches!(op, ChunkOp::HtoD { .. })), 4);
                assert_eq!(count_ops(&plans, |op| matches!(op, ChunkOp::DtoH { .. })), 4);
                assert_eq!(count_ops(&plans, |op| matches!(op, ChunkOp::Evict { .. })), 0);
                assert_eq!(
                    count_ops(&plans, |op| matches!(op, ChunkOp::Resident { .. })),
                    (plans.len() - 1) * 4,
                    "{} on {n_dev} devices",
                    scheme.name()
                );
                // HtoD drops by the epoch count vs the staged plan.
                assert_eq!(
                    summary.staged_htod_bytes,
                    summary.planned_htod_bytes * plans.len() as u64,
                    "{}",
                    scheme.name()
                );
                for plan in &plans {
                    check_causality(plan);
                }
            }
        }
    }

    #[test]
    fn resident_tight_cap_spills_every_epoch() {
        let dc = dc();
        let devs = DeviceAssignment::contiguous(4, 2);
        let (plans, summary) = plan_run_resident(
            Scheme::So2dr,
            &dc,
            &devs,
            kind(),
            20,
            8,
            4,
            &ResidencyConfig::auto(1, 3),
        );
        assert!(summary.enabled);
        assert!(!summary.fits, "a 1-byte capacity cannot fit the model");
        assert!(summary.kept.iter().all(|&k| !k));
        // Every chunk spills at the end of every non-final epoch...
        assert_eq!(summary.planned_spills, (plans.len() - 1) * 4);
        // ... so the host sees as many bytes as the staged plan.
        assert_eq!(summary.planned_htod_bytes, summary.staged_htod_bytes);
        assert_eq!(summary.saved_htod_bytes(), 0);
        for plan in &plans {
            check_causality(plan);
        }
    }

    #[test]
    fn resident_off_and_incore_and_single_epoch_degenerate_to_staged() {
        let dc = dc();
        let devs = DeviceAssignment::single(4);
        for (scheme, cfg, n) in [
            (Scheme::So2dr, ResidencyConfig::off(), 20),
            (Scheme::InCore, ResidencyConfig::force(3), 20),
            (Scheme::So2dr, ResidencyConfig::force(3), 6), // single epoch
        ] {
            let (plans, summary) = plan_run_resident(scheme, &dc, &devs, kind(), n, 8, 4, &cfg);
            assert!(!summary.enabled);
            assert_eq!(summary.saved_htod_bytes(), 0);
            for p in &plans {
                assert!(!p.resident);
                for (_, _, op) in p.iter_ops() {
                    assert!(!matches!(
                        op,
                        ChunkOp::Resident { .. } | ChunkOp::Fetch(_) | ChunkOp::Evict { .. }
                    ));
                }
            }
        }
    }

    #[test]
    fn resident_epoch_fetches_match_publishes_exactly() {
        // RS keys are exact (rect, time): every fetch must find a
        // same-key publish, on the right device.
        let dc = dc();
        for (scheme, k_on) in [(Scheme::So2dr, 2), (Scheme::ResReu, 1)] {
            let devs = DeviceAssignment::contiguous(4, 4);
            let (plans, _) =
                plan_run_resident(
                    scheme,
                    &dc,
                    &devs,
                    kind(),
                    20,
                    5,
                    k_on,
                    &ResidencyConfig::force(3),
                );
            for plan in plans.iter().skip(1) {
                let mut published: HashSet<(Rect, usize, usize)> = HashSet::new();
                for cp in &plan.chunks {
                    for op in &cp.ops[..phase_a_len(&cp.ops)] {
                        match op {
                            ChunkOp::RsWrite(r) => {
                                published.insert((r.rect, r.time_step, cp.device));
                            }
                            ChunkOp::D2D { dst_dev, rect, time_step, .. } => {
                                published.insert((*rect, *time_step, *dst_dev));
                            }
                            _ => {}
                        }
                    }
                }
                for cp in &plan.chunks {
                    for op in &cp.ops {
                        if let ChunkOp::Fetch(r) = op {
                            assert!(
                                published.contains(&(r.rect, r.time_step, cp.device)),
                                "{}: chunk {} fetch {} has no same-device publish",
                                scheme.name(),
                                cp.chunk,
                                r.rect
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn phase_a_covers_arrival_and_publishes_only() {
        let dc = dc();
        let devs = DeviceAssignment::contiguous(4, 2);
        // Staged epoch: phase A is the HtoD (chunk 1 reads before writing).
        let staged = so2dr_epoch(&dc, &devs, kind(), 8, 4, 0);
        assert_eq!(phase_a_len(&staged.chunks[1].ops), 1);
        // Resident epoch: marker + publishes (+ link hops), then fetches.
        let (plans, _) = plan_run_resident(
            Scheme::So2dr,
            &dc,
            &devs,
            kind(),
            20,
            8,
            4,
            &ResidencyConfig::force(3),
        );
        let mid = &plans[1];
        for cp in &mid.chunks {
            let a = phase_a_len(&cp.ops);
            assert!(a >= 1, "arrival op");
            assert!(matches!(cp.ops[0], ChunkOp::Resident { .. }));
            for op in &cp.ops[a..] {
                assert!(
                    !matches!(op, ChunkOp::Resident { .. } | ChunkOp::HtoD { .. }),
                    "arrival ops confined to phase A"
                );
            }
            // Interior chunks fetch both skirts.
            if cp.chunk > 0 && cp.chunk < 3 {
                let fetches =
                    cp.ops.iter().filter(|o| matches!(o, ChunkOp::Fetch(_))).count();
                assert_eq!(fetches, 2, "chunk {}", cp.chunk);
            }
        }
    }
}

#[cfg(test)]
mod tile_tests {
    use super::*;

    /// The load-bearing degenerate-equivalence check: a 1xN tiling (one
    /// tile column) must reproduce the 1-D row-band plan op-for-op —
    /// same rects, same codecs, same order, same device placement.
    #[test]
    fn tile_plans_degenerate_to_row_plans() {
        let (rows, cols, d, r) = (240usize, 64usize, 4usize, 2usize);
        let dc1 = Decomposition::new(rows, cols, d, r);
        let dc2 = Decomposition2d::try_new(rows, cols, d, 1, r).unwrap();
        for n_dev in [1usize, 2, 4] {
            let devs = DeviceAssignment::contiguous(d, n_dev);
            let kind = StencilKind::Box { radius: r };
            let rows_plans = plan_run_devices(Scheme::So2dr, &dc1, &devs, kind, 20, 8, 4);
            let tile_plans = plan_run_tiles(Scheme::So2dr, &dc2, &devs, kind, 20, 8, 4).unwrap();
            assert_eq!(rows_plans.len(), tile_plans.len());
            for (a, b) in rows_plans.iter().zip(&tile_plans) {
                assert_eq!(a.steps, b.steps);
                assert_eq!(a.start_step, b.start_step);
                assert_eq!(a.n_devices, b.n_devices);
                assert_eq!(a.chunks.len(), b.chunks.len());
                for (ca, cb) in a.chunks.iter().zip(&b.chunks) {
                    assert_eq!(ca.chunk, cb.chunk);
                    assert_eq!(ca.device, cb.device);
                    assert_eq!(ca.ops, cb.ops, "chunk {} on {n_dev} devices", ca.chunk);
                }
            }
        }
    }

    /// The ResReu analog of the degenerate-equivalence check: with one
    /// tile column the west/east skew bands are empty and every step's
    /// op run collapses to the 1-D `RsWrite -> RsRead -> Kernel` shape,
    /// so the tile plan must equal the row plan op-for-op.
    #[test]
    fn resreu_tile_plans_degenerate_to_row_plans() {
        let (rows, cols, d, r) = (240usize, 64usize, 4usize, 2usize);
        let dc1 = Decomposition::new(rows, cols, d, r);
        let dc2 = Decomposition2d::try_new(rows, cols, d, 1, r).unwrap();
        for n_dev in [1usize, 2, 4] {
            let devs = DeviceAssignment::contiguous(d, n_dev);
            let kind = StencilKind::Box { radius: r };
            let rows_plans = plan_run_devices(Scheme::ResReu, &dc1, &devs, kind, 15, 5, 1);
            let tile_plans = plan_run_tiles(Scheme::ResReu, &dc2, &devs, kind, 15, 5, 1).unwrap();
            assert_eq!(rows_plans.len(), tile_plans.len());
            for (a, b) in rows_plans.iter().zip(&tile_plans) {
                assert_eq!(a.steps, b.steps);
                assert_eq!(a.start_step, b.start_step);
                assert_eq!(a.n_devices, b.n_devices);
                assert_eq!(a.chunks.len(), b.chunks.len());
                for (ca, cb) in a.chunks.iter().zip(&b.chunks) {
                    assert_eq!(ca.chunk, cb.chunk);
                    assert_eq!(ca.device, cb.device);
                    assert_eq!(ca.ops, cb.ops, "chunk {} on {n_dev} devices", ca.chunk);
                }
            }
        }
    }

    #[test]
    fn tile_epoch_structure_interior_tile() {
        // 3x3 tiles: the center tile reads north + west, writes south +
        // east, and runs ceil(steps/k_on) kernels.
        let dc = Decomposition2d::try_new(120, 120, 3, 3, 1).unwrap();
        let plan = so2dr_tiles_epoch(
            &dc,
            &DeviceAssignment::single(9),
            StencilKind::Box { radius: 1 },
            6,
            4,
            0,
        );
        let center = &plan.chunks[4]; // tile (1,1)
        assert!(matches!(center.ops[0], ChunkOp::HtoD { .. }));
        let reads = center.ops.iter().filter(|o| matches!(o, ChunkOp::RsRead(_))).count();
        let writes = center.ops.iter().filter(|o| matches!(o, ChunkOp::RsWrite(_))).count();
        let kernels = center.ops.iter().filter(|o| matches!(o, ChunkOp::Kernel(_))).count();
        assert_eq!((reads, writes, kernels), (2, 2, 2));
        assert!(matches!(center.ops.last(), Some(ChunkOp::DtoH { .. })));
        // Corner tiles: (0,0) reads nothing, writes south + east;
        // (2,2) reads north + west, writes nothing.
        let nw = &plan.chunks[0];
        assert_eq!(nw.ops.iter().filter(|o| matches!(o, ChunkOp::RsRead(_))).count(), 0);
        assert_eq!(nw.ops.iter().filter(|o| matches!(o, ChunkOp::RsWrite(_))).count(), 2);
        let se = &plan.chunks[8];
        assert_eq!(se.ops.iter().filter(|o| matches!(o, ChunkOp::RsRead(_))).count(), 2);
        assert_eq!(se.ops.iter().filter(|o| matches!(o, ChunkOp::RsWrite(_))).count(), 0);
    }

    #[test]
    fn tile_reads_precede_writes_precede_kernels() {
        // Publishes must extract epoch-start data: every RsWrite sits
        // after the tile's reads (its band may include read data) and
        // before its first kernel (which would overwrite it).
        let dc = Decomposition2d::try_new(90, 110, 3, 2, 1).unwrap();
        let plan = so2dr_tiles_epoch(
            &dc,
            &DeviceAssignment::contiguous(6, 3),
            StencilKind::Box { radius: 1 },
            5,
            2,
            0,
        );
        for cp in &plan.chunks {
            let first_kernel =
                cp.ops.iter().position(|o| matches!(o, ChunkOp::Kernel(_))).unwrap();
            let last_read = cp
                .ops
                .iter()
                .rposition(|o| matches!(o, ChunkOp::RsRead(_)))
                .unwrap_or(0);
            for (oi, op) in cp.ops.iter().enumerate() {
                if matches!(op, ChunkOp::RsWrite(_)) {
                    assert!(oi < first_kernel, "tile {}: write after kernel", cp.chunk);
                    assert!(oi > last_read, "tile {}: write before a read", cp.chunk);
                }
            }
        }
    }

    /// The rejection matrix after closing the ResReu x tiles lattice
    /// cell: both out-of-core schemes plan over tiles; only in-core —
    /// which has no decomposition at all — is still refused.
    #[test]
    fn tile_scheme_rejection_matrix_shrank_to_incore_only() {
        let dc = Decomposition2d::try_new(96, 96, 2, 2, 1).unwrap();
        let devs = DeviceAssignment::single(4);
        let kind = StencilKind::Box { radius: 1 };
        for (scheme, k_on, accepted) in [
            (Scheme::So2dr, 4usize, true),
            (Scheme::ResReu, 1, true),
            (Scheme::InCore, 4, false),
        ] {
            let got = plan_run_tiles(scheme, &dc, &devs, kind, 8, 4, k_on);
            match got {
                Ok(plans) => {
                    assert!(accepted, "{} must be rejected over tiles", scheme.name());
                    assert!(!plans.is_empty());
                }
                Err(err) => {
                    assert!(!accepted, "{} must plan over tiles: {err}", scheme.name());
                    assert!(err.to_string().contains("incore"), "{err}");
                }
            }
        }
    }

    #[test]
    fn plan_run_tiles_rejects_infeasible_tilings() {
        // 4x4 tiles of 8x8 cells cannot host an s_tb=8 skirt at r=1.
        let dc = Decomposition2d::try_new(32, 32, 4, 4, 1).unwrap();
        let devs = DeviceAssignment::single(16);
        let kind = StencilKind::Box { radius: 1 };
        let err = plan_run_tiles(Scheme::So2dr, &dc, &devs, kind, 16, 8, 4).unwrap_err();
        assert!(err.to_string().contains("infeasible"), "{err}");
        // But a single-step epoch fits (skirt 1 + r 1 <= 8).
        assert!(plan_run_tiles(Scheme::So2dr, &dc, &devs, kind, 4, 1, 1).is_ok());
    }

    #[test]
    fn tile_epoch_split_matches_row_split() {
        let dc = Decomposition2d::try_new(96, 96, 2, 2, 1).unwrap();
        let devs = DeviceAssignment::single(4);
        let plans =
            plan_run_tiles(Scheme::So2dr, &dc, &devs, StencilKind::Box { radius: 1 }, 20, 8, 4)
                .unwrap();
        assert_eq!(plans.len(), 3);
        assert_eq!(plans[0].steps, 8);
        assert_eq!(plans[2].steps, 4);
        assert_eq!(plans[2].start_step, 16);
        assert!(plans.iter().all(|p| !p.resident));
    }

    #[test]
    fn tile_transfers_partition_the_grid() {
        let dc = Decomposition2d::try_new(100, 120, 2, 3, 2).unwrap();
        let plan = so2dr_tiles_epoch(
            &dc,
            &DeviceAssignment::single(6),
            StencilKind::Box { radius: 2 },
            4,
            2,
            0,
        );
        for pick in [0usize, 1] {
            let mut cover = vec![0u8; 100 * 120];
            for (_, _, op) in plan.iter_ops() {
                let rect = match (pick, op) {
                    (0, ChunkOp::HtoD { rect, .. }) => rect,
                    (1, ChunkOp::DtoH { rect, .. }) => rect,
                    _ => continue,
                };
                for r in rect.r0..rect.r1 {
                    for c in rect.c0..rect.c1 {
                        cover[r * 120 + c] += 1;
                    }
                }
            }
            assert!(cover.iter().all(|&x| x == 1), "direction {pick} must partition");
        }
    }
}

#[cfg(test)]
mod resident_tile_tests {
    use super::*;

    fn dc2() -> Decomposition2d {
        Decomposition2d::try_new(120, 96, 2, 3, 2).unwrap()
    }

    fn kind() -> StencilKind {
        StencilKind::Box { radius: 2 }
    }

    fn count_ops(plans: &[EpochPlan], f: impl Fn(&ChunkOp) -> bool) -> usize {
        plans.iter().flat_map(|p| p.iter_ops()).filter(|&(_, _, op)| f(op)).count()
    }

    #[test]
    fn resident_tiles_force_transfers_each_tile_once() {
        let dc = dc2();
        for n_dev in [1usize, 2, 6] {
            let devs = DeviceAssignment::contiguous(6, n_dev);
            let (plans, summary) = plan_run_resident_tiles(
                Scheme::So2dr,
                &dc,
                &devs,
                kind(),
                12,
                4,
                2,
                &ResidencyConfig::force(3),
            )
            .unwrap();
            assert_eq!(plans.len(), 3);
            assert!(summary.enabled && summary.fits);
            assert!(summary.kept.iter().all(|&k| k));
            assert_eq!(summary.planned_spills, 0);
            // One HtoD per tile (first touch), one DtoH per tile (final
            // writeback), markers everywhere in between.
            assert_eq!(count_ops(&plans, |op| matches!(op, ChunkOp::HtoD { .. })), 6);
            assert_eq!(count_ops(&plans, |op| matches!(op, ChunkOp::DtoH { .. })), 6);
            assert_eq!(count_ops(&plans, |op| matches!(op, ChunkOp::Evict { .. })), 0);
            assert_eq!(
                count_ops(&plans, |op| matches!(op, ChunkOp::Resident { .. })),
                (plans.len() - 1) * 6,
                "{n_dev} devices"
            );
            // HtoD drops by the epoch count vs the staged tile plan.
            assert_eq!(
                summary.staged_htod_bytes,
                summary.planned_htod_bytes * plans.len() as u64,
                "{n_dev} devices"
            );
        }
    }

    #[test]
    fn resident_tiles_tight_cap_spills_every_epoch() {
        let dc = dc2();
        let devs = DeviceAssignment::contiguous(6, 2);
        let (plans, summary) = plan_run_resident_tiles(
            Scheme::So2dr,
            &dc,
            &devs,
            kind(),
            12,
            4,
            2,
            &ResidencyConfig::auto(1, 3),
        )
        .unwrap();
        assert!(summary.enabled);
        assert!(!summary.fits, "a 1-byte capacity cannot fit the model");
        assert!(summary.kept.iter().all(|&k| !k));
        assert_eq!(summary.planned_spills, (plans.len() - 1) * 6);
        assert_eq!(summary.planned_htod_bytes, summary.staged_htod_bytes);
        assert_eq!(summary.saved_htod_bytes(), 0);
    }

    #[test]
    fn resident_tiles_off_and_single_epoch_degenerate_to_staged() {
        let dc = dc2();
        let devs = DeviceAssignment::single(6);
        for (cfg, n) in [
            (ResidencyConfig::off(), 12),
            (ResidencyConfig::force(3), 4), // single epoch
        ] {
            let (plans, summary) =
                plan_run_resident_tiles(Scheme::So2dr, &dc, &devs, kind(), n, 4, 2, &cfg)
                    .unwrap();
            assert!(!summary.enabled);
            assert_eq!(summary.saved_htod_bytes(), 0);
            for p in &plans {
                assert!(!p.resident);
                for (_, _, op) in p.iter_ops() {
                    assert!(!matches!(
                        op,
                        ChunkOp::Resident { .. } | ChunkOp::Fetch(_) | ChunkOp::Evict { .. }
                    ));
                }
            }
        }
    }

    /// The shrunk resident-tile rejection matrix: ResReu now plans and
    /// pins tiles like SO2DR; only the in-core scheme is refused.
    #[test]
    fn resident_tile_scheme_rejection_matrix_shrank_to_incore_only() {
        let dc = Decomposition2d::try_new(96, 96, 2, 2, 1).unwrap();
        let devs = DeviceAssignment::single(4);
        let k = StencilKind::Box { radius: 1 };
        let (plans, summary) = plan_run_resident_tiles(
            Scheme::ResReu,
            &dc,
            &devs,
            k,
            8,
            4,
            1,
            &ResidencyConfig::force(3),
        )
        .unwrap();
        assert_eq!(plans.len(), 2);
        assert!(summary.enabled && summary.fits);
        assert!(plans[1].resident);
        let err = plan_run_resident_tiles(
            Scheme::InCore,
            &dc,
            &devs,
            k,
            8,
            4,
            1,
            &ResidencyConfig::force(3),
        )
        .unwrap_err();
        assert!(err.to_string().contains("incore"), "{err}");
    }

    /// Middle resident tile epochs carry the three-round grammar, and
    /// [`resident_pass_bounds`] splits exactly at the two fetch runs;
    /// 1-D-shaped chunk-epochs keep their two-pass split.
    #[test]
    fn resident_pass_bounds_detect_the_tile_grammar() {
        let dc = dc2();
        let devs = DeviceAssignment::single(6);
        let kept = vec![true; 6];
        let mid = resident_tiles_epoch(Scheme::So2dr, &dc, &devs, kind(), 4, 2, 4, 4, &kept, false);
        for cp in &mid.chunks {
            let b = resident_pass_bounds(&cp.ops);
            assert_eq!(b.len(), 4, "tile {}: {b:?}", cp.chunk);
            assert_eq!((b[0], *b.last().unwrap()), (0, cp.ops.len()));
            // Pass 0: arrival + column publishes only.
            for op in &cp.ops[b[0]..b[1]] {
                assert!(matches!(
                    op,
                    ChunkOp::Resident { .. }
                        | ChunkOp::HtoD { .. }
                        | ChunkOp::RsWrite(_)
                        | ChunkOp::D2D { .. }
                ));
            }
            // Pass 1 starts with a fetch and contains no kernels.
            assert!(matches!(cp.ops[b[1]], ChunkOp::Fetch(_)));
            for op in &cp.ops[b[1]..b[2]] {
                assert!(!matches!(op, ChunkOp::Kernel(_)));
            }
            // Pass 2 starts with a fetch and holds all kernels.
            assert!(matches!(cp.ops[b[2]], ChunkOp::Fetch(_)));
            assert!(cp.ops[b[2]..].iter().any(|op| matches!(op, ChunkOp::Kernel(_))));
        }
        // 1-D resident chunk-epochs stay two-pass.
        let dc1 = Decomposition::new(240, 64, 4, 2);
        let devs1 = DeviceAssignment::contiguous(4, 2);
        let (plans, _) = plan_run_resident(
            Scheme::So2dr,
            &dc1,
            &devs1,
            kind(),
            20,
            8,
            4,
            &ResidencyConfig::force(3),
        );
        for cp in &plans[1].chunks {
            let b = resident_pass_bounds(&cp.ops);
            assert_eq!(b.len(), 3, "chunk {}: {b:?}", cp.chunk);
            assert_eq!(b[1], phase_a_len(&cp.ops));
        }
    }

    /// The load-bearing degenerate-equivalence check: a one-tile-column
    /// resident tiling must reproduce the 1-D resident plan op-for-op —
    /// same rects, same order, same keep decisions, same devices.
    #[test]
    fn resident_tile_plans_degenerate_to_resident_row_plans() {
        let (rows, cols, d, r) = (240usize, 64usize, 4usize, 2usize);
        let dc1 = Decomposition::new(rows, cols, d, r);
        let dc2 = Decomposition2d::try_new(rows, cols, d, 1, r).unwrap();
        for n_dev in [1usize, 2, 4] {
            let devs = DeviceAssignment::contiguous(d, n_dev);
            let k = StencilKind::Box { radius: r };
            let (rows_plans, rows_summary) = plan_run_resident(
                Scheme::So2dr,
                &dc1,
                &devs,
                k,
                20,
                8,
                4,
                &ResidencyConfig::force(3),
            );
            let tile = plan_run_resident_tiles(
                Scheme::So2dr,
                &dc2,
                &devs,
                k,
                20,
                8,
                4,
                &ResidencyConfig::force(3),
            )
            .unwrap();
            let (tile_plans, tile_summary) = tile;
            assert_eq!(rows_summary.kept, tile_summary.kept);
            assert_eq!(rows_summary.planned_spills, tile_summary.planned_spills);
            assert_eq!(rows_summary.planned_htod_bytes, tile_summary.planned_htod_bytes);
            assert_eq!(rows_plans.len(), tile_plans.len());
            for (a, b) in rows_plans.iter().zip(&tile_plans) {
                assert_eq!(a.steps, b.steps);
                assert_eq!(a.start_step, b.start_step);
                assert_eq!(a.resident, b.resident);
                assert_eq!(a.chunks.len(), b.chunks.len());
                for (ca, cb) in a.chunks.iter().zip(&b.chunks) {
                    assert_eq!(ca.chunk, cb.chunk);
                    assert_eq!(ca.device, cb.device);
                    assert_eq!(ca.ops, cb.ops, "chunk {} on {n_dev} devices", ca.chunk);
                }
            }
        }
    }

    /// RS keys are exact (rect, time): every fetch of a resident tile
    /// epoch must find a same-key publish on its own device in an
    /// earlier pass.
    #[test]
    fn resident_tile_fetches_match_publishes_per_pass() {
        use std::collections::HashSet;
        let dc = dc2();
        let devs = DeviceAssignment::contiguous(6, 3);
        let (plans, _) = plan_run_resident_tiles(
            Scheme::So2dr,
            &dc,
            &devs,
            kind(),
            12,
            4,
            2,
            &ResidencyConfig::force(3),
        )
        .unwrap();
        for plan in plans.iter().skip(1) {
            let mut published: HashSet<(Rect, usize)> = HashSet::new();
            for segments in resident_pass_sequences(plan) {
                // Fetches of this pass see only earlier passes' publishes.
                for (ci, range) in &segments {
                    let cp = &plan.chunks[*ci];
                    for op in &cp.ops[range.clone()] {
                        if let ChunkOp::Fetch(r) = op {
                            assert!(
                                published.contains(&(r.rect, cp.device)),
                                "tile {} (dev {}) fetch {} has no earlier-pass \
                                 same-device publish",
                                cp.chunk,
                                cp.device,
                                r.rect
                            );
                        }
                    }
                }
                // Then register this pass's publishes (and link landings).
                for (ci, range) in segments {
                    let cp = &plan.chunks[ci];
                    for op in &cp.ops[range] {
                        match op {
                            ChunkOp::RsWrite(r) => {
                                published.insert((r.rect, cp.device));
                            }
                            ChunkOp::D2D { dst_dev, rect, .. } => {
                                published.insert((*rect, *dst_dev));
                            }
                            _ => {}
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod incore_tests {
    use super::*;

    /// Accept/reject table for the validated in-core epoch constructor,
    /// mirroring the decomposition constructor tables: every rejection
    /// names the violated constraint instead of silently planning an
    /// empty interior (the old `radius.min(rows)` clamping) or tripping
    /// a bare assert.
    #[test]
    fn incore_epoch_acceptance_table() {
        let accept: &[(usize, usize, usize, usize, usize)] = &[
            (100, 64, 1, 10, 4),
            (3, 3, 1, 1, 1), // smallest grid with an interior cell
            (100, 100, 4, 7, 3),
        ];
        for &(rows, cols, r, steps, k_on) in accept {
            let plan = try_incore_epoch(rows, cols, StencilKind::Box { radius: r }, steps, k_on, 0)
                .unwrap_or_else(|e| panic!("({rows},{cols},r{r},{steps},{k_on}): {e}"));
            assert_eq!(plan.steps, steps);
            for (_, _, op) in plan.iter_ops() {
                let ChunkOp::Kernel(k) = op else {
                    panic!("in-core plans hold kernels only, got {op:?}");
                };
                for w in &k.windows {
                    assert!(!w.is_empty(), "accepted plans never hold empty windows");
                }
            }
        }
        let reject: &[(usize, usize, usize, usize, usize, &str)] = &[
            (100, 64, 1, 0, 4, "steps"),
            (100, 64, 1, 10, 0, "k_on"),
            (100, 64, 0, 10, 4, "radius"),
            (2, 64, 1, 10, 4, "rows extent"),  // rows == 2r
            (1, 64, 1, 10, 4, "rows extent"),  // radius >= rows
            (100, 2, 1, 10, 4, "cols extent"), // cols == 2r
            (100, 8, 4, 10, 4, "cols extent"), // cols == 2r at r=4
        ];
        for &(rows, cols, r, steps, k_on, needle) in reject {
            let err = try_incore_epoch(rows, cols, StencilKind::Box { radius: r }, steps, k_on, 0)
                .expect_err(&format!("({rows},{cols},r{r},{steps},{k_on}) accepted"));
            assert!(
                err.to_string().contains(needle),
                "({rows},{cols},r{r}): {err} missing {needle:?}"
            );
        }
    }

    #[test]
    fn incore_epoch_panics_with_the_validated_message() {
        let got = std::panic::catch_unwind(|| {
            incore_epoch(2, 64, StencilKind::Box { radius: 1 }, 10, 4, 0)
        });
        let msg = *got.unwrap_err().downcast::<String>().unwrap();
        assert!(msg.contains("invalid in-core epoch"), "{msg}");
        assert!(msg.contains("rows extent"), "{msg}");
    }
}

#[cfg(test)]
mod pipeline_plan_tests {
    use super::*;

    fn count_ops(plans: &[EpochPlan], f: impl Fn(&ChunkOp) -> bool) -> usize {
        plans.iter().flat_map(|p| p.iter_ops()).filter(|&(_, _, op)| f(op)).count()
    }

    fn segments() -> Vec<(StencilKind, usize, usize)> {
        vec![
            (StencilKind::Box { radius: 1 }, 8, 4),
            (StencilKind::Box { radius: 2 }, 6, 3),
            (StencilKind::Gradient2d, 4, 4),
        ]
    }

    /// The cross-segment chain: one cold HtoD per chunk at the head of
    /// the pipeline, one DtoH per chunk at its tail, resident arrivals
    /// everywhere in between — including at both segment boundaries,
    /// where the stencil kind (and radius) changes under the arenas.
    #[test]
    fn pipeline_chain_transfers_each_chunk_once_across_segments() {
        let d = 4usize;
        for n_dev in [1usize, 2, 4] {
            let devs = DeviceAssignment::contiguous(d, n_dev);
            let (plans, summary) = plan_pipeline_resident(
                240,
                64,
                d,
                &devs,
                &segments(),
                2,
                &ResidencyConfig::force(3),
            )
            .unwrap();
            // Epoch splits per segment: 8/4 -> 2, 6/3 -> 2, 4/4 -> 1.
            assert_eq!(plans.len(), 5);
            let starts: Vec<usize> = plans.iter().map(|p| p.start_step).collect();
            assert_eq!(starts, vec![0, 4, 8, 11, 14], "globally re-based and monotone");
            let kinds: Vec<StencilKind> = plans.iter().map(|p| p.kind).collect();
            assert_eq!(
                kinds,
                vec![
                    StencilKind::Box { radius: 1 },
                    StencilKind::Box { radius: 1 },
                    StencilKind::Box { radius: 2 },
                    StencilKind::Box { radius: 2 },
                    StencilKind::Gradient2d,
                ],
                "every epoch records its segment's stencil kind"
            );
            assert!(plans.iter().all(|p| p.scheme == Scheme::So2dr && p.resident));
            assert!(summary.enabled && summary.fits);
            assert!(summary.kept.iter().all(|&k| k));
            assert_eq!(summary.planned_spills, 0);
            // One HtoD per chunk (pipeline head), one DtoH per chunk
            // (pipeline tail), resident markers everywhere else.
            assert_eq!(count_ops(&plans, |op| matches!(op, ChunkOp::HtoD { .. })), d);
            assert_eq!(count_ops(&plans, |op| matches!(op, ChunkOp::DtoH { .. })), d);
            assert_eq!(count_ops(&plans, |op| matches!(op, ChunkOp::Evict { .. })), 0);
            assert_eq!(
                count_ops(&plans, |op| matches!(op, ChunkOp::Resident { .. })),
                (plans.len() - 1) * d
            );
            assert!(
                plans[..4].iter().all(|p| p
                    .iter_ops()
                    .all(|(_, _, op)| !matches!(op, ChunkOp::DtoH { .. }))),
                "no writeback before the final epoch"
            );
            // The planned HtoD is exactly one grid (owned spans partition
            // the rows); staged would pay it once per epoch.
            assert_eq!(summary.planned_htod_bytes, 240 * 64 * 4);
            assert_eq!(summary.staged_htod_bytes, 240 * 64 * 4 * plans.len() as u64);
        }
    }

    /// Off-mode and degenerate-input behavior of the pipeline planner.
    #[test]
    fn pipeline_plan_degenerates_and_rejects() {
        let d = 4usize;
        let devs = DeviceAssignment::contiguous(d, 2);
        // Off: concatenated staged segments, summary disabled.
        let (plans, summary) =
            plan_pipeline_resident(240, 64, d, &devs, &segments(), 2, &ResidencyConfig::off())
                .unwrap();
        assert_eq!(plans.len(), 5);
        assert!(!summary.enabled);
        assert_eq!(summary.saved_htod_bytes(), 0);
        assert!(plans.iter().all(|p| !p.resident));
        assert_eq!(count_ops(&plans, |op| matches!(op, ChunkOp::HtoD { .. })), 5 * d);
        // Tight auto cap: every chunk spills at every non-final epoch.
        let (plans, summary) =
            plan_pipeline_resident(240, 64, d, &devs, &segments(), 2, &ResidencyConfig::auto(1, 3))
                .unwrap();
        assert!(summary.enabled && !summary.fits);
        assert_eq!(summary.planned_spills, (plans.len() - 1) * d);
        assert_eq!(summary.planned_htod_bytes, summary.staged_htod_bytes);
        // Rejections name the offending input.
        let err = plan_pipeline_resident(240, 64, d, &devs, &[], 2, &ResidencyConfig::force(3))
            .unwrap_err();
        assert!(err.to_string().contains("empty pipeline"), "{err}");
        let err = plan_pipeline_resident(
            240,
            64,
            d,
            &devs,
            &[(StencilKind::Box { radius: 2 }, 40, 40)],
            2,
            &ResidencyConfig::force(3),
        )
        .unwrap_err();
        assert!(err.to_string().contains("infeasible"), "{err}");
    }
}
