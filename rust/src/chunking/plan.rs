//! Epoch plans: the schedule IR produced by the coordinator and consumed by
//! both interpreters (the real-numerics executor and the device simulator).
//!
//! Algorithm 1 of the paper maps onto this IR directly: an outer loop over
//! epochs (`N_t = ceil(n / k_off)`, last epoch possibly short), an inner
//! loop over chunks, and per chunk the op sequence
//! `HtoD -> RS read -> RS write -> kernels -> DtoH` (SO2DR) or
//! `HtoD -> (RS read/write + 1-step kernel) * steps -> DtoH` (ResReu).

use super::decomp::{Decomposition, DeviceAssignment};
use crate::core::geom::RowSpan;

/// Out-of-core sharing scheme.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Scheme {
    /// The paper's contribution: trapezoid sharing + redundant compute,
    /// multi-step (`k_on`) kernels.
    So2dr,
    /// Jin et al. 2013 baseline: intermediate-result reuse, single-step
    /// kernels.
    ResReu,
    /// Whole grid resident; no per-epoch transfers (paper §V-D).
    InCore,
}

impl Scheme {
    pub fn name(&self) -> &'static str {
        match self {
            Scheme::So2dr => "so2dr",
            Scheme::ResReu => "resreu",
            Scheme::InCore => "incore",
        }
    }

    pub fn parse(s: &str) -> Option<Scheme> {
        match s {
            "so2dr" => Some(Scheme::So2dr),
            "resreu" => Some(Scheme::ResReu),
            "incore" => Some(Scheme::InCore),
            _ => None,
        }
    }
}

/// A region-sharing copy (device-to-device) in global row coordinates.
/// `time_step` is the epoch-local time index of the data being moved
/// (0 = epoch-start raw data) — used by tests to validate causality.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RegionOp {
    pub span: RowSpan,
    pub time_step: usize,
}

/// One fused kernel launch: `windows[t]` is the compute-row window of
/// fused step `t` (global coordinates, already clamped to the Dirichlet
/// interior). `first_step` is the 1-based epoch-local index of the first
/// fused step.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct KernelInvocation {
    pub first_step: usize,
    pub windows: Vec<RowSpan>,
}

impl KernelInvocation {
    pub fn fused_steps(&self) -> usize {
        self.windows.len()
    }

    /// Total compute area in rows (summed over fused steps).
    pub fn window_rows(&self) -> usize {
        self.windows.iter().map(|w| w.len()).sum()
    }
}

/// One operation in a chunk's epoch sequence.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ChunkOp {
    HtoD { span: RowSpan },
    RsRead(RegionOp),
    RsWrite(RegionOp),
    /// Peer-to-peer halo exchange: move the `(span, time_step)` region
    /// just published by this chunk's `RsWrite` from `src_dev`'s sharing
    /// buffer to `dst_dev`'s, across the inter-device link. Emitted only
    /// when the producing and consuming chunks live on different devices;
    /// the consumer's `RsRead` then hits its own device's buffer.
    ///
    /// Naming note: this is the *inter-device* transfer — the flattener
    /// maps it to `OpKind::P2p`, priced by the link channel. It is
    /// unrelated to `OpKind::D2D`, which is the *on-device* sharing copy
    /// produced by `RsWrite`/`RsRead` (the paper's "O/D" category).
    D2D { src_dev: usize, dst_dev: usize, span: RowSpan, time_step: usize },
    Kernel(KernelInvocation),
    DtoH { span: RowSpan },
}

/// All ops of one chunk within one epoch, in execution order.
#[derive(Debug, Clone)]
pub struct ChunkEpochPlan {
    pub chunk: usize,
    /// Device the chunk is sharded onto (0 for single-device runs).
    pub device: usize,
    pub ops: Vec<ChunkOp>,
}

/// One epoch: `steps` TB steps (`k'_off` in Algorithm 1) across all chunks.
#[derive(Debug, Clone)]
pub struct EpochPlan {
    pub scheme: Scheme,
    /// Epoch-local number of TB steps (`k'_off`).
    pub steps: usize,
    /// First global time-step index covered by this epoch (0-based).
    pub start_step: usize,
    /// Devices the epoch is sharded over (1 = the seed's single-GPU plan).
    pub n_devices: usize,
    pub chunks: Vec<ChunkEpochPlan>,
}

impl EpochPlan {
    /// Iterate `(chunk_index_in_plan, op_index, op)` in the canonical
    /// sequential execution order (chunk-major).
    pub fn iter_ops(&self) -> impl Iterator<Item = (usize, usize, &ChunkOp)> {
        self.chunks
            .iter()
            .enumerate()
            .flat_map(|(ci, c)| c.ops.iter().enumerate().map(move |(oi, op)| (ci, oi, op)))
    }

    pub fn n_ops(&self) -> usize {
        self.chunks.iter().map(|c| c.ops.len()).sum()
    }
}

/// Build one SO2DR epoch (Algorithm 1 lines 4–16) of `steps` TB steps with
/// `k_on`-step fused kernels, sharded over `devs`. When the consumer of a
/// region share lives on another device, the share is followed by a
/// [`ChunkOp::D2D`] halo exchange over the inter-device link.
pub fn so2dr_epoch(
    dc: &Decomposition,
    devs: &DeviceAssignment,
    steps: usize,
    k_on: usize,
    start_step: usize,
) -> EpochPlan {
    assert!(steps >= 1 && k_on >= 1);
    assert_eq!(devs.n_chunks(), dc.n_chunks(), "device assignment shape mismatch");
    dc.check(steps);
    let mut chunks = Vec::with_capacity(dc.n_chunks());
    for i in 0..dc.n_chunks() {
        let mut ops = Vec::new();
        ops.push(ChunkOp::HtoD { span: dc.so2dr_htod(i, steps) });
        let rs_read = dc.so2dr_rs_read(i, steps);
        if !rs_read.is_empty() {
            ops.push(ChunkOp::RsRead(RegionOp { span: rs_read, time_step: 0 }));
        }
        let rs_write = dc.so2dr_rs_write(i, steps);
        if !rs_write.is_empty() {
            ops.push(ChunkOp::RsWrite(RegionOp { span: rs_write, time_step: 0 }));
            if devs.crosses_boundary(i) {
                ops.push(ChunkOp::D2D {
                    src_dev: devs.device_of(i),
                    dst_dev: devs.device_of(i + 1),
                    span: rs_write,
                    time_step: 0,
                });
            }
        }
        // Lines 7–14: ceil(steps / k_on) kernels, the last possibly short.
        let mut s = 1usize;
        while s <= steps {
            let fused = k_on.min(steps - s + 1);
            let windows: Vec<RowSpan> =
                (0..fused).map(|t| dc.so2dr_window(i, steps, s + t)).collect();
            ops.push(ChunkOp::Kernel(KernelInvocation { first_step: s, windows }));
            s += fused;
        }
        ops.push(ChunkOp::DtoH { span: dc.so2dr_dtoh(i) });
        chunks.push(ChunkEpochPlan { chunk: i, device: devs.device_of(i), ops });
    }
    EpochPlan {
        scheme: Scheme::So2dr,
        steps,
        start_step,
        n_devices: devs.n_devices(),
        chunks,
    }
}

/// Build one ResReu epoch: single-step kernels interleaved with RS
/// reads/writes of intermediate results (paper Fig. 2b), sharded over
/// `devs` with per-step [`ChunkOp::D2D`] exchanges at device boundaries.
pub fn resreu_epoch(
    dc: &Decomposition,
    devs: &DeviceAssignment,
    steps: usize,
    start_step: usize,
) -> EpochPlan {
    assert!(steps >= 1);
    assert_eq!(devs.n_chunks(), dc.n_chunks(), "device assignment shape mismatch");
    dc.check(steps);
    let mut chunks = Vec::with_capacity(dc.n_chunks());
    for i in 0..dc.n_chunks() {
        let mut ops = Vec::new();
        ops.push(ChunkOp::HtoD { span: dc.resreu_htod(i) });
        for s in 1..=steps {
            // Write our trailing rows (time s-1) for the upper neighbor,
            // then read our lower halo (time s-1) from the lower neighbor.
            let w = dc.resreu_rs_write(i, s);
            if !w.is_empty() {
                ops.push(ChunkOp::RsWrite(RegionOp { span: w, time_step: s - 1 }));
                if devs.crosses_boundary(i) {
                    ops.push(ChunkOp::D2D {
                        src_dev: devs.device_of(i),
                        dst_dev: devs.device_of(i + 1),
                        span: w,
                        time_step: s - 1,
                    });
                }
            }
            let r = dc.resreu_rs_read(i, s);
            if !r.is_empty() {
                ops.push(ChunkOp::RsRead(RegionOp { span: r, time_step: s - 1 }));
            }
            ops.push(ChunkOp::Kernel(KernelInvocation {
                first_step: s,
                windows: vec![dc.resreu_window(i, steps, s)],
            }));
        }
        ops.push(ChunkOp::DtoH { span: dc.resreu_dtoh(i, steps) });
        chunks.push(ChunkEpochPlan { chunk: i, device: devs.device_of(i), ops });
    }
    EpochPlan {
        scheme: Scheme::ResReu,
        steps,
        start_step,
        n_devices: devs.n_devices(),
        chunks,
    }
}

/// Build the in-core "epoch": the whole grid is one resident chunk and all
/// `steps` are applied as `k_on`-fused kernels over the full interior.
/// No HtoD/DtoH ops are emitted (the paper excludes the two one-time
/// transfers from the in-core measurements, §V-D).
pub fn incore_epoch(
    rows: usize,
    radius: usize,
    steps: usize,
    k_on: usize,
    start_step: usize,
) -> EpochPlan {
    assert!(steps >= 1 && k_on >= 1);
    let interior = RowSpan::new(radius.min(rows), rows.saturating_sub(radius).max(radius.min(rows)));
    let mut ops = Vec::new();
    let mut s = 1usize;
    while s <= steps {
        let fused = k_on.min(steps - s + 1);
        ops.push(ChunkOp::Kernel(KernelInvocation {
            first_step: s,
            windows: vec![interior; fused],
        }));
        s += fused;
    }
    EpochPlan {
        scheme: Scheme::InCore,
        steps,
        start_step,
        n_devices: 1,
        chunks: vec![ChunkEpochPlan { chunk: 0, device: 0, ops }],
    }
}

/// Split a total of `n` steps into epochs of at most `s_tb` (Algorithm 1
/// lines 1–3) and build the per-epoch plans, sharded over `devs`. The
/// in-core scheme is inherently single-device and ignores the assignment.
pub fn plan_run_devices(
    scheme: Scheme,
    dc: &Decomposition,
    devs: &DeviceAssignment,
    n: usize,
    s_tb: usize,
    k_on: usize,
) -> Vec<EpochPlan> {
    assert!(n >= 1 && s_tb >= 1);
    let mut plans = Vec::new();
    let mut done = 0usize;
    while done < n {
        let steps = s_tb.min(n - done);
        let plan = match scheme {
            Scheme::So2dr => so2dr_epoch(dc, devs, steps, k_on, done),
            Scheme::ResReu => resreu_epoch(dc, devs, steps, done),
            Scheme::InCore => incore_epoch(dc.rows(), dc.radius(), steps, k_on, done),
        };
        plans.push(plan);
        done += steps;
    }
    plans
}

/// Single-device [`plan_run_devices`] (the seed's original entry point).
pub fn plan_run(
    scheme: Scheme,
    dc: &Decomposition,
    n: usize,
    s_tb: usize,
    k_on: usize,
) -> Vec<EpochPlan> {
    plan_run_devices(scheme, dc, &DeviceAssignment::single(dc.n_chunks()), n, s_tb, k_on)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dc() -> Decomposition {
        Decomposition::new(240, 64, 4, 2)
    }

    fn one_dev() -> DeviceAssignment {
        DeviceAssignment::single(4)
    }

    #[test]
    fn so2dr_epoch_structure() {
        let plan = so2dr_epoch(&dc(), &one_dev(), 8, 4, 0);
        assert_eq!(plan.chunks.len(), 4);
        let c1 = &plan.chunks[1];
        // HtoD, RsRead, RsWrite, 2 kernels (8/4), DtoH.
        assert_eq!(c1.ops.len(), 6);
        assert!(matches!(c1.ops[0], ChunkOp::HtoD { .. }));
        assert!(matches!(c1.ops[1], ChunkOp::RsRead(_)));
        assert!(matches!(c1.ops[2], ChunkOp::RsWrite(_)));
        assert!(matches!(c1.ops[3], ChunkOp::Kernel(_)));
        assert!(matches!(c1.ops[5], ChunkOp::DtoH { .. }));
        // First chunk has no RsRead; last no RsWrite.
        assert!(!plan.chunks[0].ops.iter().any(|o| matches!(o, ChunkOp::RsRead(_))));
        assert!(!plan.chunks[3].ops.iter().any(|o| matches!(o, ChunkOp::RsWrite(_))));
    }

    #[test]
    fn so2dr_residual_kernel() {
        let plan = so2dr_epoch(&dc(), &one_dev(), 7, 4, 0);
        let kernels: Vec<&KernelInvocation> = plan.chunks[0]
            .ops
            .iter()
            .filter_map(|o| match o {
                ChunkOp::Kernel(k) => Some(k),
                _ => None,
            })
            .collect();
        assert_eq!(kernels.len(), 2);
        assert_eq!(kernels[0].fused_steps(), 4);
        assert_eq!(kernels[1].fused_steps(), 3); // k'_off % k_on
        assert_eq!(kernels[1].first_step, 5);
    }

    #[test]
    fn resreu_epoch_structure() {
        let plan = resreu_epoch(&dc(), &one_dev(), 5, 0);
        let c1 = &plan.chunks[1];
        // HtoD + 5*(write+read+kernel) + DtoH
        assert_eq!(c1.ops.len(), 1 + 5 * 3 + 1);
        // All kernels single-step.
        for op in &c1.ops {
            if let ChunkOp::Kernel(k) = op {
                assert_eq!(k.fused_steps(), 1);
            }
        }
    }

    #[test]
    fn plan_run_epoch_split() {
        let plans = plan_run(Scheme::So2dr, &dc(), 20, 8, 4);
        assert_eq!(plans.len(), 3);
        assert_eq!(plans[0].steps, 8);
        assert_eq!(plans[2].steps, 4); // n % s_tb
        assert_eq!(plans[2].start_step, 16);
    }

    #[test]
    fn incore_plan_has_no_transfers() {
        let plans = plan_run(Scheme::InCore, &dc(), 10, 10, 4);
        assert_eq!(plans.len(), 1);
        for (_, _, op) in plans[0].iter_ops() {
            assert!(matches!(op, ChunkOp::Kernel(_)));
        }
        // ceil(10/4) = 3 kernels.
        assert_eq!(plans[0].n_ops(), 3);
    }

    #[test]
    fn resreu_causality_pairs() {
        // RsWrite(i, s) span+time must equal RsRead(i+1, s).
        let plan = resreu_epoch(&dc(), &one_dev(), 5, 0);
        for i in 0..3 {
            let writes: Vec<&RegionOp> = plan.chunks[i]
                .ops
                .iter()
                .filter_map(|o| match o {
                    ChunkOp::RsWrite(r) => Some(r),
                    _ => None,
                })
                .collect();
            let reads: Vec<&RegionOp> = plan.chunks[i + 1]
                .ops
                .iter()
                .filter_map(|o| match o {
                    ChunkOp::RsRead(r) => Some(r),
                    _ => None,
                })
                .collect();
            assert_eq!(writes.len(), reads.len());
            for (w, r) in writes.iter().zip(&reads) {
                assert_eq!(w, r);
            }
        }
    }
}

#[cfg(test)]
mod device_tests {
    use super::*;
    use std::collections::{HashMap, HashSet};

    fn dc() -> Decomposition {
        Decomposition::new(240, 64, 4, 2)
    }

    /// Walk a plan in canonical execution order and verify plan causality:
    /// - a chunk never publishes (`RsWrite`) data of a time step it has
    ///   not yet computed (`time_step <= kernel steps completed so far`);
    /// - a `D2D` only moves a region its source device already holds;
    /// - an `RsRead` only consumes a region available on the reader's own
    ///   device;
    /// - every region a kernel step depends on arrived before the kernel
    ///   (reads precede the kernel of their `first_step` in op order).
    fn check_causality(plan: &EpochPlan) {
        // (span.lo, span.hi, time_step) -> devices holding the region.
        let mut available: HashMap<(usize, usize, usize), HashSet<usize>> = HashMap::new();
        for cp in &plan.chunks {
            let mut steps_done = 0usize;
            for op in &cp.ops {
                match op {
                    ChunkOp::RsWrite(r) => {
                        assert!(
                            r.time_step <= steps_done,
                            "chunk {} publishes t{} after only {} steps",
                            cp.chunk,
                            r.time_step,
                            steps_done
                        );
                        available
                            .entry((r.span.lo, r.span.hi, r.time_step))
                            .or_default()
                            .insert(cp.device);
                    }
                    ChunkOp::D2D { src_dev, dst_dev, span, time_step } => {
                        assert_eq!(*src_dev, cp.device, "D2D source must be the producer");
                        assert_ne!(src_dev, dst_dev, "D2D must cross devices");
                        let holders = available
                            .get(&(span.lo, span.hi, *time_step))
                            .unwrap_or_else(|| panic!("D2D of unpublished region {span}"));
                        assert!(
                            holders.contains(src_dev),
                            "D2D from dev {src_dev} which does not hold {span} @t{time_step}"
                        );
                        available
                            .entry((span.lo, span.hi, *time_step))
                            .or_default()
                            .insert(*dst_dev);
                    }
                    ChunkOp::RsRead(r) => {
                        let holders = available
                            .get(&(r.span.lo, r.span.hi, r.time_step))
                            .unwrap_or_else(|| {
                                panic!(
                                    "chunk {} reads unpublished region {} @t{}",
                                    cp.chunk, r.span, r.time_step
                                )
                            });
                        assert!(
                            holders.contains(&cp.device),
                            "chunk {} (dev {}) reads {} @t{} not on its device",
                            cp.chunk,
                            cp.device,
                            r.span,
                            r.time_step
                        );
                        // Halo data must predate the steps it feeds.
                        assert!(
                            r.time_step <= steps_done,
                            "read of future time step t{}",
                            r.time_step
                        );
                    }
                    ChunkOp::Kernel(k) => {
                        assert_eq!(k.first_step, steps_done + 1, "kernel steps out of order");
                        steps_done += k.fused_steps();
                    }
                    ChunkOp::HtoD { .. } | ChunkOp::DtoH { .. } => {}
                }
            }
            assert_eq!(steps_done, plan.steps, "chunk {} step count", cp.chunk);
        }
    }

    #[test]
    fn so2dr_causality_across_device_counts() {
        for n_dev in [1, 2, 4] {
            let devs = DeviceAssignment::contiguous(4, n_dev);
            check_causality(&so2dr_epoch(&dc(), &devs, 8, 4, 0));
        }
    }

    #[test]
    fn resreu_causality_across_device_counts() {
        for n_dev in [1, 2, 4] {
            let devs = DeviceAssignment::contiguous(4, n_dev);
            check_causality(&resreu_epoch(&dc(), &devs, 5, 0));
        }
    }

    #[test]
    fn d2d_emitted_exactly_at_device_boundaries() {
        let devs = DeviceAssignment::contiguous(4, 2); // boundary between chunks 1|2
        let plan = so2dr_epoch(&dc(), &devs, 8, 4, 0);
        for cp in &plan.chunks {
            let d2d: Vec<&ChunkOp> = cp
                .ops
                .iter()
                .filter(|o| matches!(o, ChunkOp::D2D { .. }))
                .collect();
            if cp.chunk == 1 {
                assert_eq!(d2d.len(), 1, "one raw-halo exchange per epoch at the boundary");
                if let ChunkOp::D2D { src_dev, dst_dev, span, time_step } = d2d[0] {
                    assert_eq!((*src_dev, *dst_dev, *time_step), (0, 1, 0));
                    assert_eq!(*span, dc().so2dr_rs_write(1, 8));
                }
            } else {
                assert!(d2d.is_empty(), "chunk {} must not exchange", cp.chunk);
            }
        }
    }

    #[test]
    fn resreu_d2d_one_per_step_at_boundary() {
        let devs = DeviceAssignment::contiguous(4, 4);
        let plan = resreu_epoch(&dc(), &devs, 5, 0);
        // Every non-last chunk crosses a boundary: one D2D per step.
        for cp in &plan.chunks {
            let n_d2d = cp.ops.iter().filter(|o| matches!(o, ChunkOp::D2D { .. })).count();
            if cp.chunk + 1 < 4 {
                assert_eq!(n_d2d, 5, "chunk {}", cp.chunk);
            } else {
                assert_eq!(n_d2d, 0);
            }
        }
    }

    #[test]
    fn d2d_follows_its_write_immediately() {
        let devs = DeviceAssignment::contiguous(4, 4);
        for plan in [
            so2dr_epoch(&dc(), &devs, 6, 2, 0),
            resreu_epoch(&dc(), &devs, 5, 0),
        ] {
            for cp in &plan.chunks {
                for (oi, op) in cp.ops.iter().enumerate() {
                    if let ChunkOp::D2D { span, time_step, .. } = op {
                        match &cp.ops[oi - 1] {
                            ChunkOp::RsWrite(r) => {
                                assert_eq!((r.span, r.time_step), (*span, *time_step));
                            }
                            other => panic!("D2D not preceded by its RsWrite: {other:?}"),
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn single_device_plans_have_no_d2d() {
        let devs = DeviceAssignment::single(4);
        for plan in [
            so2dr_epoch(&dc(), &devs, 8, 4, 0),
            resreu_epoch(&dc(), &devs, 5, 0),
        ] {
            assert_eq!(plan.n_devices, 1);
            for (_, _, op) in plan.iter_ops() {
                assert!(!matches!(op, ChunkOp::D2D { .. }));
            }
        }
    }
}
