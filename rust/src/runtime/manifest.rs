//! Artifact manifest: the contract between `python/compile/aot.py` (writer)
//! and the PJRT runtime (reader).
//!
//! Format (one artifact per line after the header):
//!
//! ```text
//! so2dr-artifact-manifest v1
//! name=<id> kind=<kind> k=<k> rows=<H> cols=<W> radius=<r> file=<f>
//! ```

use crate::stencil::StencilKind;
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// One AOT-compiled chunk-program variant.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArtifactEntry {
    pub name: String,
    pub kind: StencilKind,
    /// Fused steps per invocation.
    pub k: usize,
    /// Chunk-buffer shape the executable was compiled for.
    pub rows: usize,
    pub cols: usize,
    pub radius: usize,
    /// HLO text file, relative to the manifest's directory.
    pub file: String,
}

/// Parsed manifest plus its base directory.
#[derive(Debug, Clone, Default)]
pub struct ArtifactManifest {
    pub dir: PathBuf,
    pub entries: Vec<ArtifactEntry>,
}

impl ArtifactManifest {
    /// Parse `dir/manifest.txt`.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text (exposed for tests).
    pub fn parse(text: &str, dir: &Path) -> Result<Self> {
        let mut lines = text.lines().filter(|l| !l.trim().is_empty());
        match lines.next() {
            Some("so2dr-artifact-manifest v1") => {}
            Some(h) => bail!("unsupported manifest header {h:?}"),
            None => bail!("empty manifest"),
        }
        let mut entries = Vec::new();
        for (ln, line) in lines.enumerate() {
            let mut name = None;
            let mut kind = None;
            let mut k = None;
            let mut rows = None;
            let mut cols = None;
            let mut radius = None;
            let mut file = None;
            for kv in line.split_whitespace() {
                let (key, value) = kv
                    .split_once('=')
                    .with_context(|| format!("line {}: bad field {kv:?}", ln + 2))?;
                match key {
                    "name" => name = Some(value.to_string()),
                    "kind" => {
                        kind = Some(
                            StencilKind::parse(value)
                                .with_context(|| format!("unknown kind {value:?}"))?,
                        )
                    }
                    "k" => k = Some(value.parse::<usize>()?),
                    "rows" => rows = Some(value.parse::<usize>()?),
                    "cols" => cols = Some(value.parse::<usize>()?),
                    "radius" => radius = Some(value.parse::<usize>()?),
                    "file" => file = Some(value.to_string()),
                    other => bail!("line {}: unknown key {other:?}", ln + 2),
                }
            }
            let entry = ArtifactEntry {
                name: name.context("missing name")?,
                kind: kind.context("missing kind")?,
                k: k.context("missing k")?,
                rows: rows.context("missing rows")?,
                cols: cols.context("missing cols")?,
                radius: radius.context("missing radius")?,
                file: file.context("missing file")?,
            };
            if entry.kind.radius() != entry.radius {
                bail!("entry {}: radius {} inconsistent with kind", entry.name, entry.radius);
            }
            entries.push(entry);
        }
        Ok(Self { dir: dir.to_path_buf(), entries })
    }

    /// Find the variant for a (kind, fused-steps, buffer-shape) request.
    pub fn find(&self, kind: StencilKind, k: usize, rows: usize, cols: usize) -> Option<&ArtifactEntry> {
        self.entries
            .iter()
            .find(|e| e.kind == kind && e.k == k && e.rows == rows && e.cols == cols)
    }

    /// Absolute path of an entry's HLO file.
    pub fn path_of(&self, entry: &ArtifactEntry) -> PathBuf {
        self.dir.join(&entry.file)
    }

    /// All distinct kinds present.
    pub fn kinds(&self) -> Vec<StencilKind> {
        let mut v: Vec<StencilKind> = self.entries.iter().map(|e| e.kind).collect();
        v.dedup();
        v.sort_by_key(|k| k.name());
        v.dedup();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "so2dr-artifact-manifest v1\n\
        name=box2d1r_k4_144x512 kind=box2d1r k=4 rows=144 cols=512 radius=1 file=a.hlo.txt\n\
        name=gradient2d_k1_137x512 kind=gradient2d k=1 rows=137 cols=512 radius=1 file=b.hlo.txt\n";

    #[test]
    fn parses_sample() {
        let m = ArtifactManifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.entries.len(), 2);
        let e = m.find(StencilKind::Box { radius: 1 }, 4, 144, 512).unwrap();
        assert_eq!(e.name, "box2d1r_k4_144x512");
        assert_eq!(m.path_of(e), Path::new("/tmp/a/a.hlo.txt"));
        assert!(m.find(StencilKind::Box { radius: 2 }, 4, 144, 512).is_none());
    }

    #[test]
    fn rejects_bad_header() {
        assert!(ArtifactManifest::parse("nope v9\n", Path::new(".")).is_err());
        assert!(ArtifactManifest::parse("", Path::new(".")).is_err());
    }

    #[test]
    fn rejects_inconsistent_radius() {
        let bad = "so2dr-artifact-manifest v1\n\
            name=x kind=box2d2r k=1 rows=10 cols=10 radius=1 file=x.hlo.txt\n";
        assert!(ArtifactManifest::parse(bad, Path::new(".")).is_err());
    }

    #[test]
    fn rejects_missing_field() {
        let bad = "so2dr-artifact-manifest v1\nname=x kind=box2d1r k=1 rows=10 cols=10 radius=1\n";
        assert!(ArtifactManifest::parse(bad, Path::new(".")).is_err());
    }
}

#[cfg(test)]
mod kinds_tests {
    use super::*;

    #[test]
    fn kinds_are_deduped_and_sorted() {
        let text = "so2dr-artifact-manifest v1\n\
            name=a kind=box2d1r k=4 rows=10 cols=10 radius=1 file=a.hlo.txt\n\
            name=b kind=box2d1r k=1 rows=10 cols=10 radius=1 file=b.hlo.txt\n\
            name=c kind=gradient2d k=1 rows=10 cols=10 radius=1 file=c.hlo.txt\n";
        let m = ArtifactManifest::parse(text, Path::new(".")).unwrap();
        let kinds = m.kinds();
        assert_eq!(kinds.len(), 2);
        assert_eq!(kinds[0].name(), "box2d1r");
        assert_eq!(kinds[1].name(), "gradient2d");
    }
}
