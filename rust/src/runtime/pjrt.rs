//! PJRT runtime: load AOT-compiled HLO-text chunk programs and execute
//! them from the Rust hot path. Python is never involved at run time.
//!
//! Pattern (see /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! Executables are compiled lazily per artifact variant and cached.

use crate::coordinator::backend::KernelBackend;
use crate::core::{Array2, Rect};
use crate::runtime::manifest::{ArtifactEntry, ArtifactManifest};
use crate::stencil::StencilKind;
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::Path;

/// A PJRT-backed kernel backend.
pub struct PjrtBackend {
    client: xla::PjRtClient,
    manifest: ArtifactManifest,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Number of kernel executions performed (for reports).
    pub executions: u64,
}

impl PjrtBackend {
    /// Create a CPU PJRT client and load the artifact manifest from `dir`.
    pub fn from_artifacts(dir: &Path) -> Result<Self> {
        let manifest = ArtifactManifest::load(dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self { client, manifest, executables: HashMap::new(), executions: 0 })
    }

    pub fn manifest(&self) -> &ArtifactManifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn get_or_compile(&mut self, entry: &ArtifactEntry) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.executables.contains_key(&entry.name) {
            let path = self.manifest.path_of(entry);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 path")?,
            )
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling artifact {}", entry.name))?;
            self.executables.insert(entry.name.clone(), exe);
        }
        Ok(&self.executables[&entry.name])
    }

    /// Validate that the window sequence matches the executable contract:
    /// fixed interior columns, row windows free.
    fn windows_to_literal(windows: &[Rect], radius: usize, cols: usize) -> Result<xla::Literal> {
        let mut flat = Vec::with_capacity(windows.len() * 2);
        for w in windows {
            if w.c0 != radius || w.c1 != cols - radius {
                bail!(
                    "column window [{}, {}) violates the AOT contract [{}, {})",
                    w.c0,
                    w.c1,
                    radius,
                    cols - radius
                );
            }
            flat.push(w.r0 as i32);
            flat.push(w.r1 as i32);
        }
        Ok(xla::Literal::vec1(&flat).reshape(&[windows.len() as i64, 2])?)
    }
}

impl KernelBackend for PjrtBackend {
    fn run_kernel(
        &mut self,
        kind: StencilKind,
        cur: &mut Array2,
        _scratch: &mut Array2,
        windows: &[Rect],
    ) -> Result<()> {
        let (rows, cols) = (cur.rows(), cur.cols());
        let k = windows.len();
        let entry = self
            .manifest
            .find(kind, k, rows, cols)
            .with_context(|| {
                format!(
                    "no artifact for kind={} k={k} rows={rows} cols={cols}; \
                     re-run `make artifacts` with this variant (see python/compile/aot.py)",
                    kind.name()
                )
            })?
            .clone();
        let radius = entry.radius;
        let win = Self::windows_to_literal(windows, radius, cols)?;
        let buf = xla::Literal::vec1(cur.as_slice()).reshape(&[rows as i64, cols as i64])?;
        let exe = self.get_or_compile(&entry)?;
        let result = exe.execute::<xla::Literal>(&[buf, win])?[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        let out = result.to_tuple1().context("unwrapping 1-tuple result")?;
        let values = out.to_vec::<f32>()?;
        if values.len() != cur.len() {
            bail!("result size {} != buffer size {}", values.len(), cur.len());
        }
        cur.as_mut_slice().copy_from_slice(&values);
        self.executions += 1;
        Ok(())
    }

    fn name(&self) -> String {
        format!("pjrt/{}", self.client.platform_name())
    }
}
