//! Runtime: executes AOT-compiled chunk programs via PJRT (the `xla`
//! crate). Build artifacts with `make artifacts`; at run time the Rust
//! binary is self-contained.

pub mod manifest;
pub mod pjrt;

pub use manifest::{ArtifactEntry, ArtifactManifest};
pub use pjrt::PjrtBackend;

use std::path::PathBuf;

/// Default artifact directory: `$SO2DR_ARTIFACTS` or `./artifacts`.
pub fn default_artifact_dir() -> PathBuf {
    std::env::var("SO2DR_ARTIFACTS").map(PathBuf::from).unwrap_or_else(|_| PathBuf::from("artifacts"))
}
