//! Optimized host engine — the performance hot path of the real-numerics
//! backend (EXPERIMENTS.md §Perf).
//!
//! Optimizations over [`NaiveEngine`](crate::stencil::NaiveEngine):
//! 1. **Separable box convolution** — the box weight matrix is `u ⊗ v` by
//!    construction, so one step is a horizontal pass (`2r+1` MACs/elem)
//!    followed by a vertical pass, `2(2r+1)` instead of `(2r+1)^2` MACs.
//! 2. **Row-band multithreading** — the output window is split into
//!    disjoint row bands processed by scoped threads (safe split_at_mut).
//! 3. **Vertical pass walks rows, not columns** — accumulates `u(di) *
//!    tmp_row` into the output row with contiguous, auto-vectorizable
//!    inner loops.
//!
//! Numerics: separable association differs from the naive engine's 2-D
//! accumulation, so results match the reference to ~1e-5 relative, not
//! bitwise. Schedulers that must be bit-exact use the naive engine.

use crate::core::{Array2, Rect};
use crate::stencil::engine::StencilEngine;
use crate::stencil::kind::{StencilKind, GRADIENT_ALPHA};
use crate::util::threads::{parallel_row_bands, split_range};

/// Separable + multithreaded engine.
#[derive(Debug, Clone)]
pub struct OptimizedEngine {
    nthreads: usize,
}

impl Default for OptimizedEngine {
    fn default() -> Self {
        Self::new(crate::util::threads::default_threads())
    }
}

impl OptimizedEngine {
    pub fn new(nthreads: usize) -> Self {
        Self { nthreads: nthreads.max(1) }
    }

    pub fn threads(&self) -> usize {
        self.nthreads
    }

    /// Horizontal pass for rows [r_lo, r_hi): tmp[i][j - w.c0] =
    /// sum_dj v[dj] * in[i][j + dj], j in [w.c0, w.c1).
    fn hpass_rows(
        input: &Array2,
        v: &[f32],
        radius: usize,
        w: &Rect,
        r_lo: usize,
        r_hi: usize,
        tmp: &mut [f32],
    ) {
        let wcols = w.c1 - w.c0;
        for (ti, i) in (r_lo..r_hi).enumerate() {
            let row = input.row(i);
            let trow = &mut tmp[ti * wcols..(ti + 1) * wcols];
            // First tap initializes, remaining taps accumulate — contiguous
            // slices shifted by dj, auto-vectorizable.
            let first = &row[w.c0 - radius..w.c1 - radius];
            let v0 = v[0];
            for (t, &x) in trow.iter_mut().zip(first) {
                *t = v0 * x;
            }
            for (dj, &vj) in v.iter().enumerate().skip(1) {
                let shifted = &row[w.c0 - radius + dj..w.c1 - radius + dj];
                for (t, &x) in trow.iter_mut().zip(shifted) {
                    *t += vj * x;
                }
            }
        }
    }

    fn box_window(&self, radius: usize, input: &Array2, out: &mut Array2, w: Rect) {
        let u = StencilKind::box_u(radius);
        let v = StencilKind::box_v(radius);
        let wcols = w.c1 - w.c0;
        let wrows = w.r1 - w.r0;
        let cols = out.cols();

        // Split the output rows into bands; each band computes its own
        // horizontal pass over [band.r0 - radius, band.r1 + radius) and then
        // the vertical pass into its disjoint output band.
        let bands = split_range(w.r0, w.r1, self.nthreads.min(wrows.max(1)));
        if bands.is_empty() {
            return;
        }

        // Mutable output bands, carved safely with split_at_mut via the
        // row-band helper. The helper hands each closure its absolute start
        // row and the band's backing slice.
        let band_of = |start_row: usize| -> Option<(usize, usize)> {
            bands.iter().copied().find(|&(a, _)| a == start_row)
        };
        // Pack band outputs over the full row width; we only write
        // [w.c0, w.c1) within each row.
        let out_rows = out.rows();
        debug_assert!(w.r1 <= out_rows);
        // Restrict the helper to the window's rows: operate on the
        // subslice covering [w.r0, w.r1).
        let window_slab_start = w.r0 * cols;
        let window_slab_end = w.r1 * cols;
        let slab = &mut out.as_mut_slice()[window_slab_start..window_slab_end];

        parallel_row_bands(slab, cols, bands.len(), |rel_start, band_slice| {
            let abs_start = w.r0 + rel_start;
            let Some((b_lo, b_hi)) = band_of(abs_start) else { return };
            // Fused passes with a ring buffer of (2r+1) horizontally
            // filtered rows: the working set is (2r+1)*wcols floats
            // (L2-resident) instead of a whole-band tmp array — §Perf
            // iteration 1 (≈25% faster than the two-pass variant at
            // 2048², see EXPERIMENTS.md).
            let taps = 2 * radius + 1;
            let mut ring = vec![0f32; taps * wcols];
            // Prime the ring with input rows [b_lo - r, b_lo + r).
            for (slot, i) in (b_lo - radius..b_lo + radius).enumerate() {
                Self::hpass_rows(
                    input,
                    &v,
                    radius,
                    &w,
                    i,
                    i + 1,
                    &mut ring[slot * wcols..(slot + 1) * wcols],
                );
            }
            let mut acc = vec![0f32; wcols];
            for (oi, i) in (b_lo..b_hi).enumerate() {
                // Filter the newly needed bottom row i + r into the slot
                // that held row i - r - 1 (no longer needed).
                let newest = i + radius;
                let slot = (newest - (b_lo - radius)) % taps;
                Self::hpass_rows(
                    input,
                    &v,
                    radius,
                    &w,
                    newest,
                    newest + 1,
                    &mut ring[slot * wcols..(slot + 1) * wcols],
                );
                // Vertical combine: acc = sum_di u[di] * ring[row i-r+di].
                let first_slot = ((i - radius) - (b_lo - radius)) % taps;
                let r0 = &ring[first_slot * wcols..(first_slot + 1) * wcols];
                let u0 = u[0];
                for (a, &x) in acc.iter_mut().zip(r0) {
                    *a = u0 * x;
                }
                for (di, &ui) in u.iter().enumerate().skip(1) {
                    let s = ((i - radius + di) - (b_lo - radius)) % taps;
                    let trow = &ring[s * wcols..(s + 1) * wcols];
                    for (a, &x) in acc.iter_mut().zip(trow) {
                        *a += ui * x;
                    }
                }
                let orow = &mut band_slice[oi * cols + w.c0..oi * cols + w.c1];
                orow.copy_from_slice(&acc);
            }
        });
    }

    fn gradient_window(&self, input: &Array2, out: &mut Array2, w: Rect) {
        let alpha = GRADIENT_ALPHA as f32;
        let cols = out.cols();
        let slab_start = w.r0 * cols;
        let slab_end = w.r1 * cols;
        let slab = &mut out.as_mut_slice()[slab_start..slab_end];
        let wrows = w.r1 - w.r0;
        parallel_row_bands(slab, cols, self.nthreads.min(wrows.max(1)), |rel_start, band| {
            let nrows = band.len() / cols;
            for bi in 0..nrows {
                let i = w.r0 + rel_start + bi;
                let up = input.row(i - 1);
                let mid = input.row(i);
                let dn = input.row(i + 1);
                let orow = &mut band[bi * cols + w.c0..bi * cols + w.c1];
                for (oj, j) in (w.c0..w.c1).enumerate() {
                    let n = up[j];
                    let s = dn[j];
                    let wv = mid[j - 1];
                    let e = mid[j + 1];
                    let c = mid[j];
                    let lap = ((n + s) + e) + wv - 4.0 * c;
                    let gx = e - wv;
                    let gy = s - n;
                    let g2 = gx * gx + gy * gy;
                    let coef = alpha / (1.0 + g2).sqrt();
                    orow[oj] = c + coef * lap;
                }
            }
        });
    }
}

impl StencilEngine for OptimizedEngine {
    fn compute_window(&self, kind: StencilKind, input: &Array2, out: &mut Array2, w: Rect) {
        if w.is_empty() {
            return;
        }
        match kind {
            StencilKind::Box { radius } => self.box_window(radius, input, out, w),
            StencilKind::Gradient2d => self.gradient_window(input, out, w),
        }
    }

    fn name(&self) -> &'static str {
        "optimized"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stencil::engine::apply_step;
    use crate::stencil::naive::NaiveEngine;

    fn compare_engines(kind: StencilKind, rows: usize, cols: usize, window: Rect, tol: f32) {
        let input = Array2::synthetic(rows, cols, 21);
        let mut out_n = Array2::full(rows, cols, f32::NAN);
        let mut out_o = Array2::full(rows, cols, f32::NAN);
        apply_step(&NaiveEngine, kind, &input, &mut out_n, window);
        for threads in [1, 3] {
            apply_step(&OptimizedEngine::new(threads), kind, &input, &mut out_o, window);
            let d = out_n.max_abs_diff(&out_o);
            assert!(d <= tol, "{kind} threads={threads} diff={d}");
        }
    }

    #[test]
    fn box_matches_naive_all_radii() {
        for radius in 1..=4 {
            compare_engines(
                StencilKind::Box { radius },
                48,
                40,
                Rect::new(0, 48, 0, 40),
                2e-6,
            );
        }
    }

    #[test]
    fn gradient_matches_naive_bitwise() {
        // Same scalar expressions — must be bit-exact.
        let kind = StencilKind::Gradient2d;
        let input = Array2::synthetic(33, 29, 4);
        let mut a = Array2::full(33, 29, 0.0);
        let mut b = Array2::full(33, 29, 0.0);
        let w = Rect::new(1, 32, 1, 28);
        apply_step(&NaiveEngine, kind, &input, &mut a, w);
        apply_step(&OptimizedEngine::new(4), kind, &input, &mut b, w);
        assert!(a.bit_eq(&b));
    }

    #[test]
    fn partial_window_matches_naive() {
        compare_engines(StencilKind::Box { radius: 2 }, 40, 40, Rect::new(7, 23, 5, 31), 2e-6);
        compare_engines(StencilKind::Gradient2d, 40, 40, Rect::new(11, 12, 3, 37), 2e-6);
    }

    #[test]
    fn tiny_windows_ok() {
        // Single row, single col, empty.
        compare_engines(StencilKind::Box { radius: 1 }, 16, 16, Rect::new(5, 6, 5, 6), 2e-6);
        let input = Array2::synthetic(16, 16, 1);
        let mut out = input.clone();
        apply_step(
            &OptimizedEngine::new(4),
            StencilKind::Box { radius: 1 },
            &input,
            &mut out,
            Rect::new(5, 5, 5, 5),
        );
        assert!(out.bit_eq(&input));
    }

    #[test]
    fn more_threads_than_rows() {
        compare_engines(StencilKind::Box { radius: 3 }, 24, 64, Rect::new(10, 13, 3, 61), 2e-6);
    }
}
