//! Naive direct-loop engine — the golden numerical reference.
//!
//! Every other backend (optimized host engine, PJRT/Pallas artifacts, and
//! the out-of-core schedulers) is validated against this implementation.
//! Accumulation order is fixed (di-major, then dj) and mirrored by the
//! pure-jnp oracle in `python/compile/kernels/ref.py`.

use crate::core::{Array2, Rect};
use crate::stencil::engine::StencilEngine;
use crate::stencil::kind::{StencilKind, GRADIENT_ALPHA};

/// Direct-loop reference engine.
#[derive(Debug, Clone, Copy, Default)]
pub struct NaiveEngine;

impl NaiveEngine {
    fn box_window(&self, radius: usize, input: &Array2, out: &mut Array2, w: Rect) {
        let weights = StencilKind::box_weights(radius);
        let n = 2 * radius + 1;
        for i in w.r0..w.r1 {
            for j in w.c0..w.c1 {
                let mut acc = 0f32;
                for di in 0..n {
                    let row = input.row(i + di - radius);
                    let wrow = &weights[di * n..(di + 1) * n];
                    for dj in 0..n {
                        acc += wrow[dj] * row[j + dj - radius];
                    }
                }
                out[(i, j)] = acc;
            }
        }
    }

    fn gradient_window(&self, input: &Array2, out: &mut Array2, w: Rect) {
        let alpha = GRADIENT_ALPHA as f32;
        for i in w.r0..w.r1 {
            let up = input.row(i - 1);
            let mid = input.row(i);
            let dn = input.row(i + 1);
            let orow = out.row_mut(i);
            for j in w.c0..w.c1 {
                let n = up[j];
                let s = dn[j];
                let wv = mid[j - 1];
                let e = mid[j + 1];
                let c = mid[j];
                // Fixed association order (mirrored in ref.py):
                // lap = ((n + s) + e) + w - 4c
                let lap = ((n + s) + e) + wv - 4.0 * c;
                let gx = e - wv;
                let gy = s - n;
                let g2 = gx * gx + gy * gy;
                let coef = alpha / (1.0 + g2).sqrt();
                orow[j] = c + coef * lap;
            }
        }
    }
}

impl StencilEngine for NaiveEngine {
    fn compute_window(&self, kind: StencilKind, input: &Array2, out: &mut Array2, w: Rect) {
        if w.is_empty() {
            return;
        }
        debug_assert!(w.r0 >= kind.radius() && w.r1 + kind.radius() <= input.rows());
        debug_assert!(w.c0 >= kind.radius() && w.c1 + kind.radius() <= input.cols());
        match kind {
            StencilKind::Box { radius } => self.box_window(radius, input, out, w),
            StencilKind::Gradient2d => self.gradient_window(input, out, w),
        }
    }

    fn name(&self) -> &'static str {
        "naive"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stencil::engine::apply_step;

    /// A constant field is a fixed point of the (normalized) box stencil.
    #[test]
    fn box_preserves_constant_field() {
        for radius in 1..=4 {
            let k = StencilKind::Box { radius };
            let input = Array2::full(16, 16, 3.5);
            let mut out = Array2::zeros(16, 16);
            apply_step(&NaiveEngine, k, &input, &mut out, Rect::new(0, 16, 0, 16));
            let diff = input.max_abs_diff(&out);
            assert!(diff < 1e-5, "r={radius} diff={diff}");
        }
    }

    /// The gradient stencil leaves a constant field exactly unchanged
    /// (laplacian is 0).
    #[test]
    fn gradient_preserves_constant_field() {
        let input = Array2::full(12, 12, -1.25);
        let mut out = Array2::zeros(12, 12);
        apply_step(&NaiveEngine, StencilKind::Gradient2d, &input, &mut out, Rect::new(0, 12, 0, 12));
        assert!(input.bit_eq(&out));
    }

    /// Box smoothing must strictly reduce the range of a noisy field
    /// (interior cells).
    #[test]
    fn box_smooths_noise() {
        let k = StencilKind::Box { radius: 2 };
        let input = Array2::random(32, 32, 5, -1.0, 1.0);
        let mut out = Array2::zeros(32, 32);
        apply_step(&NaiveEngine, k, &input, &mut out, Rect::new(0, 32, 0, 32));
        let interior = Rect::new(2, 30, 2, 30);
        let mut in_max = 0f32;
        let mut out_max = 0f32;
        for r in interior.r0..interior.r1 {
            for c in interior.c0..interior.c1 {
                in_max = in_max.max(input[(r, c)].abs());
                out_max = out_max.max(out[(r, c)].abs());
            }
        }
        assert!(out_max < in_max * 0.9, "out {out_max} vs in {in_max}");
    }

    /// A single spike spreads exactly to radius r in one step.
    #[test]
    fn spike_spreads_to_radius() {
        for radius in 1..=3 {
            let k = StencilKind::Box { radius };
            let mut input = Array2::zeros(17, 17);
            input[(8, 8)] = 1.0;
            let mut out = Array2::zeros(17, 17);
            apply_step(&NaiveEngine, k, &input, &mut out, Rect::new(0, 17, 0, 17));
            assert!(out[(8, 8 + radius)] > 0.0);
            assert_eq!(out[(8, 8 + radius + 1)], 0.0);
            assert!(out[(8 - radius, 8)] > 0.0);
            assert_eq!(out[(8 - radius - 1, 8)], 0.0);
        }
    }

    /// Asymmetric weights: flipping the input flips the output
    /// differently (guards against accidentally symmetric kernels).
    #[test]
    fn box_is_asymmetric() {
        let k = StencilKind::Box { radius: 1 };
        let mut input = Array2::zeros(8, 8);
        input[(4, 3)] = 1.0;
        let mut out = Array2::zeros(8, 8);
        apply_step(&NaiveEngine, k, &input, &mut out, Rect::new(0, 8, 0, 8));
        assert_ne!(out[(4, 2)], out[(4, 4)], "v-weights must be asymmetric");
        assert_ne!(out[(3, 3)], out[(5, 3)], "u-weights must be asymmetric");
    }

    /// Gradient stencil damps a noisy field (diffusion) and is bounded.
    #[test]
    fn gradient_damps_noise() {
        let mut cur = Array2::random(24, 24, 9, -1.0, 1.0);
        let mut nxt = Array2::zeros(24, 24);
        let mut range0 = 0f32;
        let interior = Rect::new(1, 23, 1, 23);
        for r in interior.r0..interior.r1 {
            for c in interior.c0..interior.c1 {
                range0 = range0.max(cur[(r, c)].abs());
            }
        }
        for _ in 0..20 {
            apply_step(&NaiveEngine, StencilKind::Gradient2d, &cur, &mut nxt, interior);
            std::mem::swap(&mut cur, &mut nxt);
        }
        assert!(cur.max_abs() <= range0 * 1.01 + 1e-6);
    }
}
