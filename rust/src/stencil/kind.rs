//! Stencil kinds and their coefficients (Table III of the paper).

/// Damping coefficient of the gradient2d stencil (see [`StencilKind::Gradient2d`]).
pub const GRADIENT_ALPHA: f64 = 0.05;

/// One of the five benchmark stencils.
///
/// * `Box { radius }` — box-type stencil: a weighted average over the
///   `(2r+1) x (2r+1)` neighborhood. The weight matrix is *separable*
///   (`w(di,dj) = u(di) * v(dj)`) and mildly asymmetric so that indexing
///   bugs (e.g. transposed offsets) change results. Arithmetic intensity:
///   `2(2r+1)^2 - 1` FLOPS/element, matching Table III.
/// * `Gradient2d` — 5-point nonlinear stencil
///   `out = c + alpha * lap / sqrt(1 + |grad|^2)` (gradient-weighted
///   diffusion), 19 FLOPS/element as in Table III.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum StencilKind {
    Box { radius: usize },
    Gradient2d,
}

impl StencilKind {
    /// The five benchmarks of Table III, in paper order.
    pub fn paper_set() -> Vec<StencilKind> {
        vec![
            StencilKind::Box { radius: 1 },
            StencilKind::Box { radius: 2 },
            StencilKind::Box { radius: 3 },
            StencilKind::Box { radius: 4 },
            StencilKind::Gradient2d,
        ]
    }

    /// Stencil radius `r` (halo width per time step).
    pub fn radius(&self) -> usize {
        match self {
            StencilKind::Box { radius } => *radius,
            StencilKind::Gradient2d => 1,
        }
    }

    /// Number of points read per output element.
    pub fn points(&self) -> usize {
        match self {
            StencilKind::Box { radius } => (2 * radius + 1) * (2 * radius + 1),
            StencilKind::Gradient2d => 5,
        }
    }

    /// FLOPS per element per time step (Table III).
    pub fn flops_per_elem(&self) -> f64 {
        match self {
            StencilKind::Box { radius } => {
                let p = (2 * radius + 1) * (2 * radius + 1);
                (2 * p - 1) as f64
            }
            StencilKind::Gradient2d => 19.0,
        }
    }

    /// Benchmark name as used in the paper's figures.
    pub fn name(&self) -> String {
        match self {
            StencilKind::Box { radius } => format!("box2d{radius}r"),
            StencilKind::Gradient2d => "gradient2d".to_string(),
        }
    }

    /// Parse a benchmark name (`box2d3r`, `gradient2d`).
    pub fn parse(s: &str) -> Option<StencilKind> {
        if s == "gradient2d" {
            return Some(StencilKind::Gradient2d);
        }
        let rest = s.strip_prefix("box2d")?.strip_suffix('r')?;
        let radius: usize = rest.parse().ok()?;
        if (1..=8).contains(&radius) {
            Some(StencilKind::Box { radius })
        } else {
            None
        }
    }

    /// Row-factor weights `u(di)`, `di = -r..=r`, as f32 (computed in f64).
    ///
    /// `u(di) = (1 + 0.1*di/(r+1)) / (2r+1)`; the linear terms cancel so
    /// `sum(u) * (2r+1) = 2r+1`, i.e. `sum(u) == 1` in exact arithmetic.
    /// The same formula is implemented in `python/compile/kernels/ref.py`
    /// and must not be changed independently.
    pub fn box_u(radius: usize) -> Vec<f32> {
        let n = (2 * radius + 1) as f64;
        (-(radius as i64)..=radius as i64)
            .map(|di| ((1.0 + 0.1 * di as f64 / (radius as f64 + 1.0)) / n) as f32)
            .collect()
    }

    /// Column-factor weights `v(dj)` (slope 0.05, distinct from `u`).
    pub fn box_v(radius: usize) -> Vec<f32> {
        let n = (2 * radius + 1) as f64;
        (-(radius as i64)..=radius as i64)
            .map(|dj| ((1.0 + 0.05 * dj as f64 / (radius as f64 + 1.0)) / n) as f32)
            .collect()
    }

    /// Full `(2r+1)^2` weight table, row-major over (di, dj):
    /// `w(di,dj) = u(di) * v(dj)` (computed in f32, same as the engines).
    pub fn box_weights(radius: usize) -> Vec<f32> {
        let u = Self::box_u(radius);
        let v = Self::box_v(radius);
        let mut w = Vec::with_capacity(u.len() * v.len());
        for ui in &u {
            for vj in &v {
                w.push(ui * vj);
            }
        }
        w
    }
}

impl std::fmt::Display for StencilKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_set_matches_table_iii() {
        let set = StencilKind::paper_set();
        assert_eq!(set.len(), 5);
        assert_eq!(set[0].name(), "box2d1r");
        assert_eq!(set[4].name(), "gradient2d");
        // Arithmetic intensities from Table III.
        assert_eq!(set[0].flops_per_elem(), 17.0); // 2*9-1
        assert_eq!(set[1].flops_per_elem(), 49.0); // 2*25-1
        assert_eq!(set[2].flops_per_elem(), 97.0); // 2*49-1
        assert_eq!(set[3].flops_per_elem(), 161.0); // 2*81-1
        assert_eq!(set[4].flops_per_elem(), 19.0);
    }

    #[test]
    fn radii_and_points() {
        assert_eq!(StencilKind::Box { radius: 3 }.radius(), 3);
        assert_eq!(StencilKind::Box { radius: 3 }.points(), 49);
        assert_eq!(StencilKind::Gradient2d.radius(), 1);
        assert_eq!(StencilKind::Gradient2d.points(), 5);
    }

    #[test]
    fn parse_roundtrip() {
        for k in StencilKind::paper_set() {
            assert_eq!(StencilKind::parse(&k.name()), Some(k));
        }
        assert_eq!(StencilKind::parse("box2d9r"), None);
        assert_eq!(StencilKind::parse("nope"), None);
    }

    #[test]
    fn box_weights_normalized_and_asymmetric() {
        for r in 1..=4 {
            let w = StencilKind::box_weights(r);
            assert_eq!(w.len(), (2 * r + 1) * (2 * r + 1));
            let sum: f64 = w.iter().map(|&x| x as f64).sum();
            assert!((sum - 1.0).abs() < 1e-6, "r={r} sum={sum}");
            // Asymmetry: first != last (catches transposed/reflected offsets).
            assert_ne!(w.first(), w.last());
        }
    }

    #[test]
    fn separable_factors_normalized() {
        for r in 1..=4 {
            let su: f64 = StencilKind::box_u(r).iter().map(|&x| x as f64).sum();
            let sv: f64 = StencilKind::box_v(r).iter().map(|&x| x as f64).sum();
            assert!((su - 1.0).abs() < 1e-6);
            assert!((sv - 1.0).abs() < 1e-6);
        }
    }
}
