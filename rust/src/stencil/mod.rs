//! Stencil definitions and host compute engines.
//!
//! The five paper benchmarks (Table III): `box2d{1,2,3,4}r` — box-type
//! stencils of radius 1..4 with `(2x+1)^2` points — and `gradient2d`, a
//! 5-point nonlinear (gradient-weighted diffusion) stencil with
//! 19 FLOPS/element.
//!
//! Two host engines implement the same math:
//! - [`NaiveEngine`] — direct loops; the golden reference all other
//!   backends (optimized host, PJRT/Pallas artifacts, schedulers) are
//!   validated against.
//! - [`OptimizedEngine`] — the performance-optimized hot path: separable
//!   two-pass box convolution plus multithreaded row bands.

pub mod engine;
pub mod kind;
pub mod naive;
pub mod optimized;

pub use engine::{apply_step, multi_step, StencilEngine};
pub use kind::StencilKind;
pub use naive::NaiveEngine;
pub use optimized::OptimizedEngine;
