//! The engine trait and window-stepping helpers shared by all backends.

use crate::core::{Array2, Rect};
use crate::stencil::kind::StencilKind;

/// A host compute engine: fills `out[window]` from `input` for one time
/// step of `kind`. Cells outside `window` are NOT touched — the caller owns
/// the ping-pong frame bookkeeping (see [`apply_step`] / [`multi_step`]).
///
/// Engines must guarantee: for every cell in `window`, all `radius`
/// neighbors are read from `input` (so `window` must be at least `radius`
/// away from the array edge — callers clamp windows to the interior).
pub trait StencilEngine: Sync {
    fn compute_window(&self, kind: StencilKind, input: &Array2, out: &mut Array2, window: Rect);

    /// Engine name for reports.
    fn name(&self) -> &'static str;
}

/// Clamp a window to the interior of an `rows x cols` array for `kind`
/// (Dirichlet boundary: the outer `radius` ring is never updated).
pub fn clamp_to_interior(window: Rect, rows: usize, cols: usize, kind: StencilKind) -> Rect {
    let r = kind.radius();
    window.intersect(&Rect::new(
        r.min(rows),
        rows.saturating_sub(r),
        r.min(cols),
        cols.saturating_sub(r),
    ))
}

/// One full ping-pong step: `out` becomes the post-step state everywhere —
/// `out[window] = stencil(input)`, everything else copied from `input`.
pub fn apply_step(
    engine: &dyn StencilEngine,
    kind: StencilKind,
    input: &Array2,
    out: &mut Array2,
    window: Rect,
) {
    assert_eq!((input.rows(), input.cols()), (out.rows(), out.cols()));
    let window = clamp_to_interior(window, input.rows(), input.cols(), kind);
    // Frame copy: rows fully outside the window.
    let cols = input.cols();
    for r in 0..window.r0 {
        out.row_mut(r).copy_from_slice(input.row(r));
    }
    for r in window.r1..input.rows() {
        out.row_mut(r).copy_from_slice(input.row(r));
    }
    // Left/right column margins inside the window rows.
    for r in window.r0..window.r1 {
        if window.c0 > 0 {
            out.row_mut(r)[..window.c0].copy_from_slice(&input.row(r)[..window.c0]);
        }
        if window.c1 < cols {
            out.row_mut(r)[window.c1..].copy_from_slice(&input.row(r)[window.c1..]);
        }
    }
    engine.compute_window(kind, input, out, window);
}

/// Apply a sequence of (already clamped or not) windows, one per time step,
/// ping-ponging between `buf` and `scratch`. On return `buf` holds the
/// final state. This is the host-side contract mirror of the L1 multi-step
/// kernel: `windows.len() == k_on` and each successive window shrinks by
/// `radius` on the sides adjacent to halo working space (the trapezoid).
pub fn multi_step(
    engine: &dyn StencilEngine,
    kind: StencilKind,
    buf: &mut Array2,
    scratch: &mut Array2,
    windows: &[Rect],
) {
    assert_eq!((buf.rows(), buf.cols()), (scratch.rows(), scratch.cols()));
    let mut cur_in_buf = true; // current state lives in `buf`
    for &w in windows {
        if cur_in_buf {
            apply_step(engine, kind, buf, scratch, w);
        } else {
            apply_step(engine, kind, scratch, buf, w);
        }
        cur_in_buf = !cur_in_buf;
    }
    if !cur_in_buf {
        // Final state is in `scratch` — swap the allocations home (O(1)
        // pointer swap instead of an O(rows*cols) copy; §Perf iteration 3.
        // apply_step rewrites every cell of its output, so the stale
        // contents left in `scratch` are irrelevant to the caller).
        std::mem::swap(buf, scratch);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stencil::naive::NaiveEngine;

    #[test]
    fn clamp_respects_radius() {
        let k = StencilKind::Box { radius: 2 };
        let w = clamp_to_interior(Rect::new(0, 100, 0, 100), 100, 100, k);
        assert_eq!(w, Rect::new(2, 98, 2, 98));
    }

    #[test]
    fn apply_step_preserves_frame() {
        let k = StencilKind::Box { radius: 1 };
        let input = Array2::random(8, 8, 11, 0.0, 1.0);
        let mut out = Array2::full(8, 8, -9.0);
        apply_step(&NaiveEngine, k, &input, &mut out, Rect::new(2, 6, 2, 6));
        // Frame cells equal input.
        for r in 0..8 {
            for c in 0..8 {
                if !(2..6).contains(&r) || !(2..6).contains(&c) {
                    assert_eq!(out[(r, c)], input[(r, c)], "frame cell ({r},{c})");
                }
            }
        }
        // Window cells were written (can't equal the sentinel).
        assert_ne!(out[(3, 3)], -9.0);
    }

    #[test]
    fn multi_step_even_and_odd_counts_agree_on_location() {
        let k = StencilKind::Gradient2d;
        let base = Array2::synthetic(12, 12, 3);
        for steps in [1usize, 2, 3, 4] {
            let mut buf = base.clone();
            let mut scratch = Array2::zeros(12, 12);
            let windows: Vec<Rect> = (0..steps).map(|_| Rect::new(1, 11, 1, 11)).collect();
            multi_step(&NaiveEngine, k, &mut buf, &mut scratch, &windows);
            // Compare against manual ping-pong.
            let mut a = base.clone();
            let mut b = Array2::zeros(12, 12);
            for s in 0..steps {
                if s % 2 == 0 {
                    apply_step(&NaiveEngine, k, &a, &mut b, Rect::new(1, 11, 1, 11));
                } else {
                    apply_step(&NaiveEngine, k, &b, &mut a, Rect::new(1, 11, 1, 11));
                }
            }
            let expect = if steps % 2 == 0 { &a } else { &b };
            assert!(buf.bit_eq(expect), "steps={steps}");
        }
    }
}
