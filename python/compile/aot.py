"""AOT build: lower chunk-program variants to HLO *text* artifacts.

HLO text (not ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids that the xla crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Artifacts land in ``artifacts/`` with a ``manifest.txt`` the Rust runtime
parses:

    so2dr-artifact-manifest v1
    name=<id> kind=<kind> k=<k> rows=<H> cols=<W> radius=<r> file=<f>

Variant set: every (kind, k, rows) the default demo geometries need —
SO2DR k_on-step kernels, ResReu single-step kernels and in-core kernels
for the e2e example plus the quickstart geometry. Python runs once at
build time; the Rust binary is self-contained afterwards.
"""

from __future__ import annotations

import argparse
import os

from jax._src.lib import xla_client as xc

from . import model
from .kernels import ref


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def demo_variants():
    """The artifact set for the shipped examples (see examples/).

    e2e_paper geometry: grid 512x512, d=4 chunks (128 owned rows),
    S_TB=8, k_on=4, n divisible by S_TB.
      - SO2DR buffers: 128 + 2*8*r rows, k=4
      - ResReu buffers: 128 + 8*r + r rows, k=1
      - in-core: 512 rows, k=4
    quickstart geometry: grid 256x256, d=4, S_TB=4, k_on=2 (box2d1r +
    gradient2d).
    """
    variants = []
    for kind in ref.PAPER_KINDS:
        r = ref.kind_radius(kind)
        variants.append((kind, 4, 128 + 2 * 8 * r, 512))   # SO2DR e2e
        variants.append((kind, 1, 128 + 8 * r + r, 512))   # ResReu e2e
        variants.append((kind, 4, 512, 512))                # in-core e2e
    for kind in ("box2d1r", "gradient2d"):
        r = ref.kind_radius(kind)
        variants.append((kind, 2, 64 + 2 * 4 * r, 256))     # SO2DR quickstart
    return variants


def variant_name(kind: str, k: int, rows: int, cols: int) -> str:
    return f"{kind}_k{k}_{rows}x{cols}"


def build(outdir: str, variants=None, verbose: bool = True) -> list[str]:
    os.makedirs(outdir, exist_ok=True)
    variants = variants if variants is not None else demo_variants()
    lines = ["so2dr-artifact-manifest v1"]
    written = []
    for kind, k, rows, cols in variants:
        name = variant_name(kind, k, rows, cols)
        fname = f"{name}.hlo.txt"
        lowered = model.lower_variant(kind, k, rows, cols)
        text = to_hlo_text(lowered)
        path = os.path.join(outdir, fname)
        with open(path, "w") as f:
            f.write(text)
        r = ref.kind_radius(kind)
        lines.append(
            f"name={name} kind={kind} k={k} rows={rows} cols={cols} "
            f"radius={r} file={fname}")
        written.append(path)
        if verbose:
            print(f"  aot: {name} ({len(text)} chars)")
    with open(os.path.join(outdir, "manifest.txt"), "w") as f:
        f.write("\n".join(lines) + "\n")
    if verbose:
        print(f"  aot: manifest.txt ({len(written)} artifacts) -> {outdir}")
    return written


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts",
                    help="output directory (default: ../artifacts)")
    args = ap.parse_args()
    build(args.out)


if __name__ == "__main__":
    main()
