"""L2: the fixed-shape chunk program.

The chunk program is the unit the Rust runtime executes: a jitted function

    (buf f32[H, W], windows i32[k, 2]) -> (f32[H, W],)

applying ``k`` fused, window-masked stencil steps by calling the L1 Pallas
kernel. One AOT executable is compiled per (kind, k, H, W) variant; the
window operand makes a single executable serve every chunk position,
trapezoid phase and epoch of a run (fixed-shape AOT masking — DESIGN.md
section "Hardware adaptation").
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import ref, stencil2d


def make_chunk_program(kind: str, tile_rows: int | None = None):
    """Build the traceable chunk-program function for ``kind``.

    The fused-step count ``k`` and the buffer shape are taken from the
    arguments at trace time, so the same callable is lowered once per
    variant by :mod:`compile.aot`.
    """
    def chunk_program(buf: jnp.ndarray, windows: jnp.ndarray):
        out = stencil2d.multistep_stencil(
            buf, windows, kind=kind, tile_rows=tile_rows)
        return (out,)

    return chunk_program


def make_chunk_program_ref(kind: str):
    """Oracle variant of the chunk program (pure jnp, no Pallas)."""
    def chunk_program(buf: jnp.ndarray, windows: jnp.ndarray):
        return (ref.multistep_ref(buf, kind, windows),)

    return chunk_program


def lower_variant(kind: str, k: int, rows: int, cols: int,
                  tile_rows: int | None = None):
    """Jit-lower one chunk-program variant; returns the jax Lowered."""
    fn = make_chunk_program(kind, tile_rows=tile_rows)
    buf = jax.ShapeDtypeStruct((rows, cols), jnp.float32)
    win = jax.ShapeDtypeStruct((k, 2), jnp.int32)
    return jax.jit(fn).lower(buf, win)
