"""L1: the Pallas multi-step stencil kernel (on-chip data reuse).

The TPU re-think of AN5D's CUDA temporal blocking (DESIGN.md
section "Hardware adaptation"):

* one grid cell owns one output row-tile of ``tile_rows`` rows;
* the tile plus a ``k*r`` halo *skirt* is loaded into VMEM once
  (``pl.load`` with a dynamic, clamped row offset);
* all ``k`` fused time steps run over values held on-chip, with the valid
  region shrinking by ``r`` rows per step — tiles recompute their skirt
  instead of synchronizing with neighbors (the paper's redundant-compute
  idea, recursed from the device-memory level down to VMEM);
* only the final ``tile_rows x W`` block is written back.

Off-chip traffic per k steps is ``(tile + skirt) + tile`` instead of
``2 * tile * k`` — the on-chip reuse that single-step kernels cannot have.

Compute windows arrive as a ``(k, 2) i32`` operand (row ``[lo, hi)`` per
fused step) so one fixed-shape AOT executable serves every chunk position
and trapezoid phase; cells outside a step's window pass through unchanged.

``interpret=True`` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; real-TPU performance is *estimated structurally* (VMEM
footprint, traffic ratio) in DESIGN.md / EXPERIMENTS.md.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def pick_tile_rows(H: int, pref: int = 128) -> int:
    """Largest divisor of H not exceeding ``pref`` (so the output grid
    tiles H exactly; pallas block shapes must divide the array)."""
    t = min(pref, H)
    while H % t != 0:
        t -= 1
    return t


def vmem_bytes_estimate(tile_rows: int, W: int, k: int, radius: int) -> int:
    """Structural VMEM footprint estimate per tile: the resident slab, one
    candidate array and the mask (bytes). Used by the perf report."""
    slab = tile_rows + 2 * k * radius
    return slab * W * 4 * 2 + slab * W  # state + candidate (f32) + mask (i8)


def offchip_traffic_ratio(tile_rows: int, k: int, radius: int) -> float:
    """Off-chip traffic of k fused steps relative to k single-step sweeps
    (lower is better): ((tile+skirt) + tile) / (2 * tile * k)."""
    slab = tile_rows + 2 * k * radius
    return (slab + tile_rows) / (2.0 * tile_rows * k)


def _kernel(win_ref, x_ref, o_ref, *, kind: str, k: int, H: int, W: int,
            tile_rows: int, slab: int):
    r = ref.kind_radius(kind)
    t = pl.program_id(0)
    # Clamped slab start: interior tiles center their halo skirt; edge
    # tiles slide inward (their outer rows are Dirichlet cells anyway).
    start = jnp.clip(t * tile_rows - (slab - tile_rows) // 2, 0, H - slab)
    state = pl.load(x_ref, (pl.ds(start, slab), slice(None)))
    rows_g = start + jax.lax.broadcasted_iota(jnp.int32, (slab, 1), 0)
    cols_g = jax.lax.broadcasted_iota(jnp.int32, (1, W), 1)
    col_mask = (cols_g >= r) & (cols_g < W - r)
    for s in range(k):
        lo = win_ref[s, 0]
        hi = win_ref[s, 1]
        cand = ref.stencil_candidate(state, kind)
        mask = (rows_g >= lo) & (rows_g < hi) & col_mask
        state = jnp.where(mask, cand, state)
    out = jax.lax.dynamic_slice(state, (t * tile_rows - start, 0), (tile_rows, W))
    o_ref[...] = out


def multistep_stencil(x: jnp.ndarray, windows: jnp.ndarray, *, kind: str,
                      tile_rows: int | None = None) -> jnp.ndarray:
    """Apply ``k = windows.shape[0]`` fused masked steps of ``kind`` to the
    chunk buffer ``x`` (f32[H, W]); ``windows`` is i32[k, 2] row windows.

    Semantically identical to ``ref.multistep_ref`` — property-tested in
    ``python/tests/test_kernel.py``.
    """
    H, W = x.shape
    k = int(windows.shape[0])
    r = ref.kind_radius(kind)
    T = tile_rows if tile_rows is not None else pick_tile_rows(H)
    assert H % T == 0, f"tile_rows {T} must divide H={H}"
    slab = T + 2 * k * r
    if slab >= H:
        # Degenerate: one tile covering the whole buffer.
        T, slab = H, H
    n_tiles = H // T

    kernel = functools.partial(
        _kernel, kind=kind, k=k, H=H, W=W, tile_rows=T, slab=slab)
    return pl.pallas_call(
        kernel,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((k, 2), lambda t: (0, 0)),   # windows: whole array
            pl.BlockSpec((H, W), lambda t: (0, 0)),   # chunk buffer: whole
        ],
        out_specs=pl.BlockSpec((T, W), lambda t: (t, 0)),
        out_shape=jax.ShapeDtypeStruct((H, W), jnp.float32),
        interpret=True,
    )(windows.astype(jnp.int32), x.astype(jnp.float32))
