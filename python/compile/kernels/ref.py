"""Pure-jnp oracle for the stencil kernels.

This file is the *numerical contract* shared with the Rust host engines
(``rust/src/stencil/``): the box weights and the gradient2d expression are
computed with the exact same formulas and association order. Do not change
either side independently.

Benchmarks (paper Table III):
  box2d{1..4}r  -- separable, mildly asymmetric box stencil of radius r,
                   2*(2r+1)^2 - 1 FLOPS/element
  gradient2d    -- 5-point gradient-weighted diffusion, 19 FLOPS/element
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

GRADIENT_ALPHA = 0.05

PAPER_KINDS = ("box2d1r", "box2d2r", "box2d3r", "box2d4r", "gradient2d")


def kind_radius(kind: str) -> int:
    """Stencil radius of a benchmark name."""
    if kind == "gradient2d":
        return 1
    if kind.startswith("box2d") and kind.endswith("r"):
        return int(kind[len("box2d"):-1])
    raise ValueError(f"unknown stencil kind {kind!r}")


def box_u(radius: int) -> np.ndarray:
    """Row-factor weights; mirrors StencilKind::box_u (computed in f64)."""
    n = float(2 * radius + 1)
    di = np.arange(-radius, radius + 1, dtype=np.float64)
    return ((1.0 + 0.1 * di / (radius + 1.0)) / n).astype(np.float32)


def box_v(radius: int) -> np.ndarray:
    """Column-factor weights; mirrors StencilKind::box_v."""
    n = float(2 * radius + 1)
    dj = np.arange(-radius, radius + 1, dtype=np.float64)
    return ((1.0 + 0.05 * dj / (radius + 1.0)) / n).astype(np.float32)


def box_weights(radius: int) -> np.ndarray:
    """Full (2r+1)^2 table w(di,dj) = u(di) * v(dj), f32 (as in Rust)."""
    u, v = box_u(radius), box_v(radius)
    return (u[:, None] * v[None, :]).astype(np.float32)


def stencil_candidate(x: jnp.ndarray, kind: str) -> jnp.ndarray:
    """One-step stencil value at every cell, computed with wrap-around
    shifts. Only cells at least ``radius`` away from the edges are valid;
    callers mask invalid cells out. Accumulation order is di-major then dj
    (mirrors the Rust naive engine).
    """
    r = kind_radius(kind)
    if kind == "gradient2d":
        n = jnp.roll(x, 1, axis=0)   # x[i-1, j]
        s = jnp.roll(x, -1, axis=0)  # x[i+1, j]
        w = jnp.roll(x, 1, axis=1)   # x[i, j-1]
        e = jnp.roll(x, -1, axis=1)  # x[i, j+1]
        c = x
        lap = ((n + s) + e) + w - 4.0 * c
        gx = e - w
        gy = s - n
        g2 = gx * gx + gy * gy
        coef = jnp.float32(GRADIENT_ALPHA) / jnp.sqrt(1.0 + g2)
        return c + coef * lap
    weights = box_weights(r)
    acc = jnp.zeros_like(x)
    for di in range(-r, r + 1):
        for dj in range(-r, r + 1):
            wij = weights[di + r, dj + r]
            # rolled[i, j] == x[i + di, j + dj]
            acc = acc + wij * jnp.roll(x, (-di, -dj), axis=(0, 1))
    return acc


def masked_step(x: jnp.ndarray, kind: str, lo, hi) -> jnp.ndarray:
    """One masked time step: rows in [lo, hi) and interior columns are
    updated, everything else passes through -- the semantic contract of the
    AOT chunk program (fixed-shape + select masking)."""
    r = kind_radius(kind)
    H, W = x.shape
    cand = stencil_candidate(x, kind)
    rows = jnp.arange(H, dtype=jnp.int32)[:, None]
    cols = jnp.arange(W, dtype=jnp.int32)[None, :]
    mask = (rows >= lo) & (rows < hi) & (cols >= r) & (cols < W - r)
    return jnp.where(mask, cand, x)


def multistep_ref(x: jnp.ndarray, kind: str, windows) -> jnp.ndarray:
    """Reference k-step chunk program: ``windows`` is a (k, 2) array of
    row windows (already clamped); steps are applied in order."""
    windows = jnp.asarray(windows, dtype=jnp.int32)
    for s in range(windows.shape[0]):
        x = masked_step(x, kind, windows[s, 0], windows[s, 1])
    return x


def reference_run(x: jnp.ndarray, kind: str, n: int) -> jnp.ndarray:
    """n full-interior steps (Dirichlet boundary)."""
    r = kind_radius(kind)
    H, _ = x.shape
    for _ in range(n):
        x = masked_step(x, kind, r, H - r)
    return x
