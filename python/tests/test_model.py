"""L2: chunk-program semantics and lowering."""

import numpy as np
import jax
import jax.numpy as jnp

import sys, os
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
from compile import model
from compile.kernels import ref

TOL = dict(rtol=0, atol=3e-6)


def test_chunk_program_matches_oracle_variant():
    kind = "box2d2r"
    x = jnp.asarray(np.random.RandomState(0).rand(40, 48).astype(np.float32))
    wins = jnp.asarray([[6, 34], [8, 32]], jnp.int32)
    (a,) = model.make_chunk_program(kind, tile_rows=20)(x, wins)
    (b,) = model.make_chunk_program_ref(kind)(x, wins)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), **TOL)


def test_chunk_program_is_jittable():
    kind = "gradient2d"
    fn = jax.jit(model.make_chunk_program(kind, tile_rows=16))
    x = jnp.asarray(np.random.RandomState(1).rand(32, 32).astype(np.float32))
    wins = jnp.asarray([[4, 28]], jnp.int32)
    (a,) = fn(x, wins)
    (b,) = model.make_chunk_program_ref(kind)(x, wins)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), **TOL)


def test_lower_variant_produces_stablehlo():
    low = model.lower_variant("box2d1r", 2, 72, 256)
    txt = str(low.compiler_ir("stablehlo"))
    assert "func" in txt
    # Fixed shapes are baked in.
    assert "72x256" in txt.replace("tensor<", "")


def test_windows_as_runtime_operand():
    """One lowered executable serves different windows (the whole point
    of the fixed-shape masking contract)."""
    kind = "box2d1r"
    fn = jax.jit(model.make_chunk_program(kind, tile_rows=18))
    x = jnp.asarray(np.random.RandomState(2).rand(36, 24).astype(np.float32))
    for lo, hi in [(1, 35), (10, 20), (18, 18)]:
        wins = jnp.asarray([[lo, hi]], jnp.int32)
        (a,) = fn(x, wins)
        b = ref.multistep_ref(x, kind, np.asarray([[lo, hi]]))
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), **TOL)
