"""AOT artifact build: manifest format and HLO text validity."""

import os
import tempfile

import sys
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
from compile import aot
from compile.kernels import ref


def test_build_small_set(tmp_path=None):
    with tempfile.TemporaryDirectory() as d:
        variants = [("box2d1r", 1, 24, 32), ("gradient2d", 2, 24, 32)]
        written = aot.build(d, variants=variants, verbose=False)
        assert len(written) == 2
        for p in written:
            with open(p) as f:
                txt = f.read()
            assert txt.startswith("HloModule")
            # return_tuple=True: root is a tuple.
            assert "tuple(" in txt or "tuple " in txt
        with open(os.path.join(d, "manifest.txt")) as f:
            lines = f.read().strip().splitlines()
        assert lines[0] == "so2dr-artifact-manifest v1"
        assert len(lines) == 3
        fields = dict(kv.split("=", 1) for kv in lines[1].split())
        assert fields["kind"] == "box2d1r"
        assert fields["k"] == "1"
        assert fields["rows"] == "24"
        assert fields["radius"] == "1"
        assert fields["file"].endswith(".hlo.txt")


def test_demo_variants_cover_paper_kinds():
    vs = aot.demo_variants()
    kinds = {v[0] for v in vs}
    assert kinds == set(ref.PAPER_KINDS)
    # Every kind has SO2DR (k=4), ResReu (k=1) and in-core (k=4, 512 rows).
    for kind in ref.PAPER_KINDS:
        ks = sorted(v[1] for v in vs if v[0] == kind)
        assert 1 in ks and 4 in ks
        assert any(v[2] == 512 for v in vs if v[0] == kind)


def test_variant_name_roundtrip():
    assert aot.variant_name("box2d3r", 4, 176, 512) == "box2d3r_k4_176x512"
