"""Cross-language numerical contract: the box weights must match the Rust
side bit for bit (StencilKind::box_u / box_v in rust/src/stencil/kind.rs).

The golden values below are independently asserted by the Rust test
`box_weights_normalized_and_asymmetric` companion assertions; if either
side changes its formula, one of the two suites fails.
"""

import struct

import numpy as np

import sys, os
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
from compile.kernels import ref


def f32_bits(x: np.float32) -> int:
    return struct.unpack("<I", struct.pack("<f", float(x)))[0]


def test_box_u_golden_bits():
    # u(di) = (1 + 0.1*di/(r+1)) / (2r+1), computed in f64 then cast.
    golden = {
        1: [(1.0 - 0.05) / 3.0, 1.0 / 3.0, (1.0 + 0.05) / 3.0],
        2: [(1.0 + 0.1 * di / 3.0) / 5.0 for di in range(-2, 3)],
        4: [(1.0 + 0.1 * di / 5.0) / 9.0 for di in range(-4, 5)],
    }
    for r, expect in golden.items():
        u = ref.box_u(r)
        for a, b in zip(u, expect):
            assert f32_bits(a) == f32_bits(np.float32(b)), (r, a, b)


def test_weights_are_exact_products():
    for r in (1, 2, 3, 4):
        w = ref.box_weights(r)
        u, v = ref.box_u(r), ref.box_v(r)
        for i in range(2 * r + 1):
            for j in range(2 * r + 1):
                assert f32_bits(w[i, j]) == f32_bits(np.float32(u[i]) * np.float32(v[j]))


def test_gradient_constants_match_rust():
    # GRADIENT_ALPHA in ref.py vs rust stencil::kind::GRADIENT_ALPHA.
    assert ref.GRADIENT_ALPHA == 0.05
