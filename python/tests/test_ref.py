"""Oracle invariants: physics/shape sanity of the pure-jnp reference."""

import numpy as np
import jax.numpy as jnp
import pytest

import sys, os
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
from compile.kernels import ref


@pytest.mark.parametrize("kind", ref.PAPER_KINDS)
def test_constant_field_is_fixed_point(kind):
    x = jnp.full((24, 24), 1.75, jnp.float32)
    y = ref.reference_run(x, kind, 3)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=2e-6)


@pytest.mark.parametrize("kind", ref.PAPER_KINDS)
def test_frame_passthrough(kind):
    r = ref.kind_radius(kind)
    x = jnp.asarray(np.random.RandomState(1).rand(20, 20).astype(np.float32))
    y = ref.reference_run(x, kind, 2)
    xs, ys = np.asarray(x), np.asarray(y)
    # Dirichlet ring unchanged, bitwise.
    np.testing.assert_array_equal(ys[:r, :], xs[:r, :])
    np.testing.assert_array_equal(ys[-r:, :], xs[-r:, :])
    np.testing.assert_array_equal(ys[:, :r], xs[:, :r])
    np.testing.assert_array_equal(ys[:, -r:], xs[:, -r:])


def test_box_weights_normalized_and_match_rust_formula():
    for r in range(1, 5):
        w = ref.box_weights(r)
        assert w.shape == (2 * r + 1, 2 * r + 1)
        assert abs(float(w.sum()) - 1.0) < 1e-6
        # Spot-check the closed form at the corner (f64 then cast).
        n = float(2 * r + 1)
        u0 = np.float32((1.0 - 0.1 * r / (r + 1.0)) / n)
        v0 = np.float32((1.0 - 0.05 * r / (r + 1.0)) / n)
        assert w[0, 0] == np.float32(u0 * v0)


def test_spike_spreads_to_radius():
    for kind in ("box2d1r", "box2d3r"):
        r = ref.kind_radius(kind)
        x = np.zeros((19, 19), np.float32)
        x[9, 9] = 1.0
        y = np.asarray(ref.reference_run(jnp.asarray(x), kind, 1))
        assert y[9, 9 + r] != 0.0
        assert y[9, 9 + r + 1] == 0.0


def test_masked_step_window_semantics():
    x = jnp.asarray(np.random.RandomState(2).rand(16, 16).astype(np.float32))
    y = np.asarray(ref.masked_step(x, "box2d1r", 5, 9))
    xs = np.asarray(x)
    np.testing.assert_array_equal(y[:5, :], xs[:5, :])
    np.testing.assert_array_equal(y[9:, :], xs[9:, :])
    assert (y[5:9, 1:15] != xs[5:9, 1:15]).any()


def test_empty_window_is_identity():
    x = jnp.asarray(np.random.RandomState(3).rand(12, 12).astype(np.float32))
    y = ref.masked_step(x, "gradient2d", 6, 6)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))


def test_gradient_is_damped_diffusion():
    x = jnp.asarray(np.random.RandomState(4).rand(32, 32).astype(np.float32))
    y = ref.reference_run(x, "gradient2d", 10)
    assert float(jnp.max(jnp.abs(y))) <= float(jnp.max(jnp.abs(x))) + 1e-5
    # Interior variance strictly decreases (smoothing).
    vi = float(jnp.var(x[4:-4, 4:-4]))
    vo = float(jnp.var(y[4:-4, 4:-4]))
    assert vo < vi


def test_kind_radius_parsing():
    assert ref.kind_radius("box2d4r") == 4
    assert ref.kind_radius("gradient2d") == 1
    with pytest.raises(ValueError):
        ref.kind_radius("nope")
