"""L1 correctness: Pallas multi-step kernel vs the pure-jnp oracle.

The CORE correctness signal of the Python layer. Includes a hypothesis
sweep over shapes, kinds, fused-step counts and window sequences.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

import sys, os
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
from compile.kernels import ref, stencil2d

TOL = dict(rtol=0, atol=3e-6)  # pallas-interpret vs eager jnp: ~1 ULP (FMA)


def run_both(x, kind, windows, tile_rows=None):
    a = stencil2d.multistep_stencil(
        jnp.asarray(x), jnp.asarray(windows), kind=kind, tile_rows=tile_rows)
    b = ref.multistep_ref(jnp.asarray(x), kind, windows)
    return np.asarray(a), np.asarray(b)


def trapezoid_windows(H, r, k, lo0, hi0):
    """Shrinking windows: lo += r, hi -= r each step (clamped)."""
    wins = []
    lo, hi = lo0, hi0
    for _ in range(k):
        wins.append([lo, max(lo, hi)])
        lo, hi = lo + r, hi - r
    return np.asarray(wins, np.int32)


@pytest.mark.parametrize("kind", ref.PAPER_KINDS)
@pytest.mark.parametrize("k", [1, 2, 4])
def test_kernel_matches_ref(kind, k):
    r = ref.kind_radius(kind)
    H, W = 48, 64
    x = np.random.RandomState(7).rand(H, W).astype(np.float32)
    wins = trapezoid_windows(H, r, k, r + k * r, H - r - k * r)
    a, b = run_both(x, kind, wins, tile_rows=16)
    np.testing.assert_allclose(a, b, **TOL)


@pytest.mark.parametrize("tile_rows", [8, 16, 24, 48])
def test_tiling_is_seamless(tile_rows):
    """Different tile sizes must agree (redundant skirt compute works)."""
    kind, k = "box2d2r", 3
    H, W = 48, 32
    x = np.random.RandomState(8).rand(H, W).astype(np.float32)
    wins = trapezoid_windows(H, 2, k, 8, 40)
    a, b = run_both(x, kind, wins, tile_rows=tile_rows)
    np.testing.assert_allclose(a, b, **TOL)


def test_single_tile_degenerate_path():
    kind = "box2d1r"
    H, W = 24, 16
    x = np.random.RandomState(9).rand(H, W).astype(np.float32)
    wins = trapezoid_windows(H, 1, 4, 5, 19)
    # tile_rows == H forces slab >= H -> single-tile path.
    a, b = run_both(x, kind, wins, tile_rows=H)
    np.testing.assert_allclose(a, b, **TOL)


def test_moving_windows_resreu_style():
    """Skewed (shifting, non-shrinking) windows also work."""
    kind, r = "box2d1r", 1
    H, W = 40, 32
    x = np.random.RandomState(10).rand(H, W).astype(np.float32)
    wins = np.asarray([[20 - s, 36 - s] for s in range(4)], np.int32)
    a, b = run_both(x, kind, wins, tile_rows=20)
    np.testing.assert_allclose(a, b, **TOL)


def test_empty_window_passthrough():
    x = np.random.RandomState(11).rand(32, 32).astype(np.float32)
    wins = np.asarray([[12, 12]], np.int32)
    a, _ = run_both(x, "gradient2d", wins, tile_rows=16)
    np.testing.assert_array_equal(a, x)


def test_pick_tile_rows_divides():
    for H in (48, 137, 144, 512, 7):
        t = stencil2d.pick_tile_rows(H)
        assert H % t == 0 and t <= 128


def test_structural_metrics():
    assert stencil2d.vmem_bytes_estimate(128, 512, 4, 1) > 0
    # Fused k=4 must cut off-chip traffic vs single-step sweeps.
    assert stencil2d.offchip_traffic_ratio(128, 4, 1) < 0.4
    assert stencil2d.offchip_traffic_ratio(128, 1, 1) >= 1.0


@settings(max_examples=25, deadline=None)
@given(
    kind=st.sampled_from(ref.PAPER_KINDS),
    k=st.integers(1, 4),
    htiles=st.integers(2, 4),
    tile=st.sampled_from([8, 16]),
    w=st.integers(18, 40),
    seed=st.integers(0, 2**31 - 1),
    data=st.data(),
)
def test_kernel_matches_ref_hypothesis(kind, k, htiles, tile, w, seed, data):
    r = ref.kind_radius(kind)
    H = htiles * tile
    x = np.random.RandomState(seed).rand(H, w).astype(np.float32)
    wins = []
    for _ in range(k):
        lo = data.draw(st.integers(r, H - r))
        hi = data.draw(st.integers(lo, H - r))
        wins.append([lo, hi])
    a, b = run_both(x, kind, np.asarray(wins, np.int32), tile_rows=tile)
    np.testing.assert_allclose(a, b, **TOL)
